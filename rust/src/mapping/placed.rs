//! Multi-span placed mappings — a model's column layout over an ordered
//! list of bitline [`Region`]s.
//!
//! [`pack_model_at`](crate::mapping::pack_model_at) generalizes the
//! packer from base 0 to one contiguous base; a [`PlacedMapping`]
//! generalizes it to **N spans**: the model's logical column sequence
//! (`0..total_bls`, exactly the order `ModelMapping::columns` yields) is
//! laid across the concatenation of the spans, so a *fragmented*
//! fleet placement — the layout region-granular allocation produces on a
//! churned pool — finally has a representable mapping. This is what lets
//! the fleet stream a tenant's weight columns into the digital twin's
//! macros span by span and run inference over the placed layout.
//!
//! Conventions: in every [`ColumnAssignment`] this module produces,
//! `global_bl` is the **logical** column index (position in the model's
//! canonical base-0 packing) while `macro_id`/`local_bl` are the
//! **physical** coordinates the spans assign. A contiguous placement at
//! base `b` ([`PlacedMapping::from_contiguous`]) reproduces
//! `pack_model_at(model, spec, b)`'s physical coordinates exactly.

use crate::arch::ModelArch;
use crate::config::MacroSpec;

use super::packer::{pack_model, ColumnAssignment, ModelMapping};
use super::region::Region;

/// One contiguous physical stretch of a logical column range (the unit a
/// macro pass or a `load_columns` call can cover in one go).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlacedRun {
    /// Physical macro hosting the run.
    pub macro_id: usize,
    /// First physical bitline of the run (local to the macro).
    pub bl_start: usize,
    /// Columns in the run.
    pub bl_count: usize,
    /// Logical column index of the run's first column.
    pub logical_start: usize,
}

/// A model packed across an ordered list of disjoint bitline spans.
#[derive(Debug, Clone, PartialEq)]
pub struct PlacedMapping {
    /// The model's canonical packing (base 0): logical column space.
    pub mapping: ModelMapping,
    /// Ordered spans; widths sum to `mapping.total_bls`.
    pub spans: Vec<Region>,
    /// Exclusive prefix sums: `starts[i]` = logical column of span `i`'s
    /// first column.
    starts: Vec<usize>,
}

impl PlacedMapping {
    /// Wrap a canonical (base-0) mapping over `spans`. Fails unless the
    /// spans are in-bounds for the spec's macros, pairwise disjoint, and
    /// sum to exactly `mapping.total_bls` columns.
    pub fn new(mapping: ModelMapping, spans: Vec<Region>) -> anyhow::Result<PlacedMapping> {
        anyhow::ensure!(
            mapping.base_bl == 0,
            "placed mappings wrap the canonical base-0 packing (got base {})",
            mapping.base_bl
        );
        let total: usize = spans.iter().map(|r| r.bl_count).sum();
        anyhow::ensure!(
            total == mapping.total_bls,
            "spans cover {total} columns but the model needs {}",
            mapping.total_bls
        );
        for (i, r) in spans.iter().enumerate() {
            anyhow::ensure!(r.bl_count > 0, "span {i} is empty");
            anyhow::ensure!(
                r.bl_end() <= mapping.spec.bitlines,
                "span {i} ({r:?}) overflows a {}-bitline macro",
                mapping.spec.bitlines
            );
            for (j, other) in spans.iter().enumerate().skip(i + 1) {
                anyhow::ensure!(
                    !r.overlaps(other),
                    "span {i} ({r:?}) overlaps span {j} ({other:?})"
                );
            }
        }
        let mut starts = Vec::with_capacity(spans.len());
        let mut acc = 0usize;
        for r in &spans {
            starts.push(acc);
            acc += r.bl_count;
        }
        Ok(PlacedMapping {
            mapping,
            spans,
            starts,
        })
    }

    /// Pack `model` and place it over `spans`.
    pub fn place_model(
        model: &ModelArch,
        spec: &MacroSpec,
        spans: Vec<Region>,
    ) -> anyhow::Result<PlacedMapping> {
        PlacedMapping::new(pack_model(model, spec), spans)
    }

    /// The degenerate contiguous placement starting at global bitline
    /// `base_bl` — one span per macro the range touches. Physically
    /// identical to `pack_model_at(model, spec, base_bl)`.
    pub fn from_contiguous(
        model: &ModelArch,
        spec: &MacroSpec,
        base_bl: usize,
    ) -> anyhow::Result<PlacedMapping> {
        let mapping = pack_model(model, spec);
        let bpm = spec.bitlines;
        let mut spans = Vec::new();
        let mut pos = base_bl;
        let end = base_bl + mapping.total_bls;
        while pos < end {
            let macro_id = pos / bpm;
            let local = pos % bpm;
            let take = (bpm - local).min(end - pos);
            spans.push(Region {
                macro_id,
                bl_start: local,
                bl_count: take,
            });
            pos += take;
        }
        PlacedMapping::new(mapping, spans)
    }

    /// Logical columns the placement covers.
    pub fn total_bls(&self) -> usize {
        self.mapping.total_bls
    }

    /// Span index containing logical column `bl`.
    fn span_of(&self, bl: usize) -> usize {
        debug_assert!(bl < self.mapping.total_bls);
        self.starts.partition_point(|&s| s <= bl) - 1
    }

    /// Physical `(macro_id, local_bl)` of logical column `bl`.
    pub fn locate(&self, bl: usize) -> (usize, usize) {
        let i = self.span_of(bl);
        let r = &self.spans[i];
        (r.macro_id, r.bl_start + (bl - self.starts[i]))
    }

    /// Spans with their logical column ranges, in logical order.
    pub fn span_ranges(&self) -> impl Iterator<Item = (Region, std::ops::Range<usize>)> + '_ {
        self.spans
            .iter()
            .zip(&self.starts)
            .map(|(r, &s)| (*r, s..s + r.bl_count))
    }

    /// Split the logical range `[logical_start, logical_start + len)` into
    /// maximal physically-contiguous runs (at most one per span touched).
    pub fn physical_runs(&self, logical_start: usize, len: usize) -> Vec<PlacedRun> {
        assert!(
            logical_start + len <= self.mapping.total_bls,
            "run [{logical_start}, {}) outside {} logical columns",
            logical_start + len,
            self.mapping.total_bls
        );
        let mut out = Vec::new();
        if len == 0 {
            return out;
        }
        let mut pos = logical_start;
        let end = logical_start + len;
        let mut si = self.span_of(pos);
        while pos < end {
            let r = &self.spans[si];
            let off = pos - self.starts[si];
            let take = (r.bl_count - off).min(end - pos);
            out.push(PlacedRun {
                macro_id: r.macro_id,
                bl_start: r.bl_start + off,
                bl_count: take,
                logical_start: pos,
            });
            pos += take;
            si += 1;
        }
        out
    }

    /// Rewrite the placement under a set of physical span moves
    /// (`(from, to)` pairs of equal width, each `from` lying entirely
    /// inside one current span — the shape the fleet's compaction
    /// planner emits). The logical column order is untouched, so every
    /// weight cell keeps its logical position and only its physical
    /// coordinates change; spans that become physically adjacent are
    /// merged, which is where a defragged placement's run count (and
    /// with it the per-segment macro pass count) actually drops.
    ///
    /// ```
    /// use cim_adapt::arch::vgg9;
    /// use cim_adapt::config::MacroSpec;
    /// use cim_adapt::mapping::{PlacedMapping, Region};
    ///
    /// let spec = MacroSpec::default();
    /// let arch = vgg9().scaled(0.04); // packs to 108 columns
    /// // A fragmented placement: two spans with a hole between them.
    /// let placed = PlacedMapping::place_model(&arch, &spec, vec![
    ///     Region { macro_id: 0, bl_start: 0, bl_count: 50 },
    ///     Region { macro_id: 0, bl_start: 100, bl_count: 58 },
    /// ]).unwrap();
    /// // Slide the tail span home; physically-adjacent spans merge.
    /// let from = Region { macro_id: 0, bl_start: 100, bl_count: 58 };
    /// let to = Region { macro_id: 0, bl_start: 50, bl_count: 58 };
    /// let moved = placed.relocate(&[(from, to)]).unwrap();
    /// assert_eq!(moved.spans.len(), 1, "defragged into one span");
    /// // Logical columns keep their identity; only coordinates changed.
    /// assert_eq!(moved.locate(0), (0, 0));
    /// assert_eq!(moved.locate(107), (0, 107));
    /// ```
    pub fn relocate(&self, moves: &[(Region, Region)]) -> anyhow::Result<PlacedMapping> {
        for (i, (from, to)) in moves.iter().enumerate() {
            anyhow::ensure!(
                from.bl_count == to.bl_count,
                "move {i} changes width: {from:?} -> {to:?}"
            );
        }
        let mut applied = 0usize;
        let mut new_spans: Vec<Region> = Vec::new();
        for span in &self.spans {
            // Moves sourced inside this span, in source order.
            let mut cuts: Vec<&(Region, Region)> = moves
                .iter()
                .filter(|(from, _)| from.overlaps(span))
                .collect();
            cuts.sort_by_key(|(from, _)| from.bl_start);
            let mut pos = span.bl_start;
            for (from, to) in cuts {
                anyhow::ensure!(
                    span.bl_start <= from.bl_start && from.bl_end() <= span.bl_end(),
                    "move source {from:?} crosses the boundary of span {span:?}"
                );
                anyhow::ensure!(
                    from.bl_start >= pos,
                    "move sources overlap inside span {span:?}"
                );
                if from.bl_start > pos {
                    new_spans.push(Region {
                        macro_id: span.macro_id,
                        bl_start: pos,
                        bl_count: from.bl_start - pos,
                    });
                }
                new_spans.push(*to);
                pos = from.bl_end();
                applied += 1;
            }
            if pos < span.bl_end() {
                new_spans.push(Region {
                    macro_id: span.macro_id,
                    bl_start: pos,
                    bl_count: span.bl_end() - pos,
                });
            }
        }
        anyhow::ensure!(
            applied == moves.len(),
            "{} move(s) do not source from this placement",
            moves.len() - applied
        );
        // Merge physically-adjacent neighbours.
        let mut merged: Vec<Region> = Vec::new();
        for s in new_spans {
            match merged.last_mut() {
                Some(last) if last.macro_id == s.macro_id && last.bl_end() == s.bl_start => {
                    last.bl_count += s.bl_count;
                }
                _ => merged.push(s),
            }
        }
        PlacedMapping::new(self.mapping.clone(), merged)
    }

    /// Every column assignment: `global_bl` logical, `macro_id`/`local_bl`
    /// physical (see the module docs).
    pub fn columns(&self) -> impl Iterator<Item = ColumnAssignment> + '_ {
        self.mapping.columns().map(move |c| {
            let (macro_id, local_bl) = self.locate(c.global_bl);
            ColumnAssignment {
                macro_id,
                local_bl,
                ..c
            }
        })
    }

    /// Distinct physical macros the placement touches, ascending.
    pub fn macros(&self) -> Vec<usize> {
        let mut ms: Vec<usize> = self.spans.iter().map(|r| r.macro_id).collect();
        ms.sort_unstable();
        ms.dedup();
        ms
    }

    /// Cells the model actually occupies (spans included or not, the
    /// weights are the same — placement moves columns, never resizes them).
    pub fn used_cells(&self) -> usize {
        self.mapping
            .layers
            .iter()
            .map(|lm| lm.rows_per_segment.iter().sum::<usize>() * lm.c_out)
            .sum()
    }

    /// Occupied cells per span, parallel to [`PlacedMapping::spans`] —
    /// sums to [`PlacedMapping::used_cells`] (every weight cell lands in
    /// exactly one span).
    pub fn span_footprints(&self) -> Vec<usize> {
        let mut cells = vec![0usize; self.spans.len()];
        for c in self.mapping.columns() {
            cells[self.span_of(c.global_bl)] += c.rows;
        }
        cells
    }

    /// Occupied cells per distinct physical macro, as sorted
    /// `(macro_id, cells)` pairs — the span-aware counterpart of
    /// [`ModelMapping::macro_footprint`].
    pub fn macro_footprint(&self) -> Vec<(usize, usize)> {
        let macros = self.macros();
        let mut cells: std::collections::BTreeMap<usize, usize> =
            macros.into_iter().map(|m| (m, 0)).collect();
        for c in self.columns() {
            *cells.get_mut(&c.macro_id).expect("column in a placed macro") += c.rows;
        }
        cells.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vgg9;
    use crate::mapping::pack_model_at;

    fn spec() -> MacroSpec {
        MacroSpec::default()
    }

    /// A fractional-macro tenant (108 columns over the default spec).
    fn small() -> ModelArch {
        vgg9().scaled(0.04)
    }

    #[test]
    fn contiguous_placement_matches_pack_model_at() {
        for base in [0usize, 100, 512, 700] {
            let placed = PlacedMapping::from_contiguous(&small(), &spec(), base).unwrap();
            let at = pack_model_at(&small(), &spec(), base);
            let placed_cols: Vec<_> = placed.columns().collect();
            let at_cols: Vec<_> = at.columns().collect();
            assert_eq!(placed_cols.len(), at_cols.len());
            for (p, a) in placed_cols.iter().zip(&at_cols) {
                // Physical coordinates agree; `global_bl` is logical for
                // the placed mapping, absolute for the offset packing.
                assert_eq!(p.macro_id, a.macro_id, "base {base}");
                assert_eq!(p.local_bl, a.local_bl, "base {base}");
                assert_eq!(p.global_bl + base, a.global_bl, "base {base}");
                assert_eq!(
                    (p.layer, p.segment, p.filter, p.rows),
                    (a.layer, a.segment, a.filter, a.rows)
                );
            }
        }
    }

    #[test]
    fn fragmented_spans_cover_all_columns_disjointly() {
        let model = small();
        let total = pack_model(&model, &spec()).total_bls; // 108
        assert_eq!(total, 108);
        let spans = vec![
            Region { macro_id: 1, bl_start: 200, bl_count: 56 },
            Region { macro_id: 0, bl_start: 10, bl_count: 30 },
            Region { macro_id: 1, bl_start: 0, bl_count: 22 },
        ];
        let placed = PlacedMapping::place_model(&model, &spec(), spans).unwrap();
        let mut seen = std::collections::BTreeSet::new();
        for c in placed.columns() {
            assert!(seen.insert((c.macro_id, c.local_bl)), "physical column reused");
        }
        assert_eq!(seen.len(), total);
        assert_eq!(placed.macros(), vec![0, 1]);
        // Logical order walks the spans in the given order.
        assert_eq!(placed.locate(0), (1, 200));
        assert_eq!(placed.locate(55), (1, 255));
        assert_eq!(placed.locate(56), (0, 10));
        assert_eq!(placed.locate(86), (1, 0));
        assert_eq!(placed.locate(107), (1, 21));
    }

    #[test]
    fn span_footprints_sum_to_used_cells() {
        let model = small();
        let spans = vec![
            Region { macro_id: 0, bl_start: 0, bl_count: 40 },
            Region { macro_id: 2, bl_start: 100, bl_count: 68 },
        ];
        let placed = PlacedMapping::place_model(&model, &spec(), spans).unwrap();
        let fp = placed.span_footprints();
        assert_eq!(fp.len(), 2);
        assert!(fp.iter().all(|&c| c > 0));
        assert_eq!(fp.iter().sum::<usize>(), placed.used_cells());
        // The macro footprint partitions the same cells by physical macro.
        let mf = placed.macro_footprint();
        assert_eq!(mf.iter().map(|&(_, c)| c).sum::<usize>(), placed.used_cells());
        assert_eq!(mf.iter().map(|&(m, _)| m).collect::<Vec<_>>(), vec![0, 2]);
    }

    #[test]
    fn physical_runs_split_at_span_boundaries_only() {
        let model = small();
        let spans = vec![
            Region { macro_id: 0, bl_start: 50, bl_count: 58 },
            Region { macro_id: 3, bl_start: 0, bl_count: 50 },
        ];
        let placed = PlacedMapping::place_model(&model, &spec(), spans).unwrap();
        let run = |macro_id, bl_start, bl_count, logical_start| PlacedRun {
            macro_id,
            bl_start,
            bl_count,
            logical_start,
        };
        // A range inside span 0 is one run.
        let runs = placed.physical_runs(10, 20);
        assert_eq!(runs, vec![run(0, 60, 20, 10)]);
        // A range crossing the boundary splits in two.
        let runs = placed.physical_runs(50, 20);
        assert_eq!(runs, vec![run(0, 100, 8, 50), run(3, 0, 12, 58)]);
        // Runs tile the whole logical space.
        let all = placed.physical_runs(0, placed.total_bls());
        assert_eq!(all.iter().map(|r| r.bl_count).sum::<usize>(), 108);
        assert!(placed.physical_runs(0, 0).is_empty());
    }

    #[test]
    fn relocate_moves_cells_and_merges_adjacent_spans() {
        let model = small(); // 108 columns
        let spans = vec![
            Region { macro_id: 0, bl_start: 0, bl_count: 60 },
            Region { macro_id: 1, bl_start: 100, bl_count: 48 },
        ];
        let placed = PlacedMapping::place_model(&model, &spec(), spans).unwrap();
        let before: Vec<_> = placed.columns().collect();
        // Slide the second span home: [m1 100..148) -> [m0 60..108).
        let mv = (
            Region { macro_id: 1, bl_start: 100, bl_count: 48 },
            Region { macro_id: 0, bl_start: 60, bl_count: 48 },
        );
        let moved = placed.relocate(&[mv]).unwrap();
        // Adjacent spans merged: the placement is now one contiguous run.
        assert_eq!(
            moved.spans,
            vec![Region { macro_id: 0, bl_start: 0, bl_count: 108 }]
        );
        assert_eq!(moved.physical_runs(0, moved.total_bls()).len(), 1);
        // Every weight cell kept its logical identity.
        let after: Vec<_> = moved.columns().collect();
        assert_eq!(before.len(), after.len());
        for (b, a) in before.iter().zip(&after) {
            assert_eq!(
                (b.global_bl, b.layer, b.segment, b.filter, b.rows),
                (a.global_bl, a.layer, a.segment, a.filter, a.rows)
            );
        }
        // A no-move relocation is the identity.
        assert_eq!(placed.relocate(&[]).unwrap().spans, placed.spans);
    }

    #[test]
    fn relocate_splits_spans_at_move_boundaries() {
        let model = small(); // 108 columns
        let spans = vec![Region { macro_id: 0, bl_start: 100, bl_count: 108 }];
        let placed = PlacedMapping::place_model(&model, &spec(), spans).unwrap();
        // Move only the middle 20 columns of the single span elsewhere.
        let mv = (
            Region { macro_id: 0, bl_start: 140, bl_count: 20 },
            Region { macro_id: 1, bl_start: 0, bl_count: 20 },
        );
        let moved = placed.relocate(&[mv]).unwrap();
        assert_eq!(
            moved.spans,
            vec![
                Region { macro_id: 0, bl_start: 100, bl_count: 40 },
                Region { macro_id: 1, bl_start: 0, bl_count: 20 },
                Region { macro_id: 0, bl_start: 160, bl_count: 48 },
            ]
        );
        assert_eq!(moved.total_bls(), 108);
    }

    #[test]
    fn relocate_rejects_bad_moves() {
        let model = small();
        let spans = vec![
            Region { macro_id: 0, bl_start: 0, bl_count: 60 },
            Region { macro_id: 1, bl_start: 0, bl_count: 48 },
        ];
        let placed = PlacedMapping::place_model(&model, &spec(), spans).unwrap();
        // Width change.
        let err = placed
            .relocate(&[(
                Region { macro_id: 0, bl_start: 0, bl_count: 60 },
                Region { macro_id: 2, bl_start: 0, bl_count: 59 },
            )])
            .unwrap_err();
        assert!(err.to_string().contains("changes width"), "{err}");
        // Source crossing a span boundary.
        let err = placed
            .relocate(&[(
                Region { macro_id: 0, bl_start: 50, bl_count: 20 },
                Region { macro_id: 2, bl_start: 0, bl_count: 20 },
            )])
            .unwrap_err();
        assert!(err.to_string().contains("crosses"), "{err}");
        // Source outside the placement entirely.
        let err = placed
            .relocate(&[(
                Region { macro_id: 3, bl_start: 0, bl_count: 10 },
                Region { macro_id: 2, bl_start: 0, bl_count: 10 },
            )])
            .unwrap_err();
        assert!(err.to_string().contains("do not source"), "{err}");
        // A move landing on another span (overlap) is caught by the
        // wrapped validation.
        let err = placed
            .relocate(&[(
                Region { macro_id: 0, bl_start: 0, bl_count: 60 },
                Region { macro_id: 1, bl_start: 10, bl_count: 60 },
            )])
            .unwrap_err();
        assert!(err.to_string().contains("overlaps"), "{err}");
    }

    #[test]
    fn invalid_spans_rejected() {
        let model = small();
        let s = spec();
        // Wrong total.
        let err = PlacedMapping::place_model(
            &model,
            &s,
            vec![Region { macro_id: 0, bl_start: 0, bl_count: 107 }],
        )
        .unwrap_err();
        assert!(err.to_string().contains("107"), "{err}");
        // Overlapping spans.
        let err = PlacedMapping::place_model(
            &model,
            &s,
            vec![
                Region { macro_id: 0, bl_start: 0, bl_count: 60 },
                Region { macro_id: 0, bl_start: 59, bl_count: 48 },
            ],
        )
        .unwrap_err();
        assert!(err.to_string().contains("overlaps"), "{err}");
        // Span overflowing the macro.
        let err = PlacedMapping::place_model(
            &model,
            &s,
            vec![Region { macro_id: 0, bl_start: 200, bl_count: 108 }],
        )
        .unwrap_err();
        assert!(err.to_string().contains("overflows"), "{err}");
    }
}
