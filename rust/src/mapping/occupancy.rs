//! Per-macro occupancy grids — the data behind Figs. 12–13.

use super::packer::ModelMapping;
use super::placed::PlacedMapping;

/// Cell ownership for one macro: `grid[wl][bl]` = layer index + 1, or 0
/// for an empty cell.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OccupancyGrid {
    /// Absolute physical macro index.
    pub macro_id: usize,
    /// Grid rows.
    pub wordlines: usize,
    /// Grid columns.
    pub bitlines: usize,
    grid: Vec<u16>,
}

impl OccupancyGrid {
    /// Build grids for every macro in a mapping. `macro_id` is the
    /// absolute macro index, so an offset packing (`pack_model_at`)
    /// yields grids labelled `first_macro()..`.
    pub fn from_mapping(map: &ModelMapping) -> Vec<OccupancyGrid> {
        let (wl, bl) = (map.spec.wordlines, map.spec.bitlines);
        let first = map.first_macro();
        let mut grids: Vec<OccupancyGrid> = (0..map.num_macros)
            .map(|m| OccupancyGrid {
                macro_id: first + m,
                wordlines: wl,
                bitlines: bl,
                grid: vec![0; wl * bl],
            })
            .collect();
        for c in map.columns() {
            let g = &mut grids[c.macro_id - first];
            for r in 0..c.rows {
                g.grid[r * bl + c.local_bl] = (c.layer + 1) as u16;
            }
        }
        grids
    }

    /// Build grids for every **physical** macro a multi-span placement
    /// touches (ascending macro id). Cells between a macro's spans stay
    /// empty — a co-resident tenant's grid shows exactly the columns it
    /// holds, which is what makes fragmentation visible in Figs. 12–13
    /// style renderings.
    pub fn from_placed(placed: &PlacedMapping) -> Vec<OccupancyGrid> {
        let (wl, bl) = (placed.mapping.spec.wordlines, placed.mapping.spec.bitlines);
        let macros = placed.macros();
        let index: std::collections::BTreeMap<usize, usize> =
            macros.iter().enumerate().map(|(i, &m)| (m, i)).collect();
        let mut grids: Vec<OccupancyGrid> = macros
            .iter()
            .map(|&m| OccupancyGrid {
                macro_id: m,
                wordlines: wl,
                bitlines: bl,
                grid: vec![0; wl * bl],
            })
            .collect();
        for c in placed.columns() {
            let g = &mut grids[index[&c.macro_id]];
            for r in 0..c.rows {
                g.grid[r * bl + c.local_bl] = (c.layer + 1) as u16;
            }
        }
        grids
    }

    /// Layer owning the cell (None = empty).
    pub fn owner(&self, wl: usize, bl: usize) -> Option<usize> {
        match self.grid[wl * self.bitlines + bl] {
            0 => None,
            l => Some(l as usize - 1),
        }
    }

    /// Fraction of cells occupied.
    pub fn fill(&self) -> f64 {
        let used = self.grid.iter().filter(|&&v| v != 0).count();
        used as f64 / self.grid.len() as f64
    }

    /// Count of occupied cells per layer present in this macro.
    pub fn per_layer_cells(&self) -> Vec<(usize, usize)> {
        let max_layer = self.grid.iter().copied().max().unwrap_or(0) as usize;
        let mut counts = vec![0usize; max_layer + 1];
        for &v in &self.grid {
            counts[v as usize] += 1;
        }
        counts
            .iter()
            .enumerate()
            .skip(1)
            .filter(|&(_, &c)| c > 0)
            .map(|(l, &c)| (l - 1, c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vgg9;
    use crate::config::MacroSpec;
    use crate::mapping::pack_model;

    #[test]
    fn grids_reconstruct_mapping_occupancy() {
        let map = pack_model(&vgg9(), &MacroSpec::default());
        let grids = OccupancyGrid::from_mapping(&map);
        assert_eq!(grids.len(), map.num_macros);
        let total_fill: f64 =
            grids.iter().map(|g| g.fill()).sum::<f64>() / grids.len() as f64;
        assert!((total_fill - map.occupancy()).abs() < 1e-9);
    }

    #[test]
    fn first_macro_starts_with_layer0() {
        let map = pack_model(&vgg9(), &MacroSpec::default());
        let grids = OccupancyGrid::from_mapping(&map);
        assert_eq!(grids[0].owner(0, 0), Some(0));
        // Layer 0 column has 27 rows: row 27 is either empty or another
        // layer never (column owned entirely by layer 0 up to rows).
        assert_eq!(grids[0].owner(26, 0), Some(0));
        assert_eq!(grids[0].owner(27, 0), None);
    }

    #[test]
    fn offset_mapping_grids_carry_absolute_macro_ids() {
        use crate::mapping::packer::pack_model_at;
        let spec = MacroSpec::default();
        let map = pack_model_at(&vgg9().scaled(0.1), &spec, 100);
        let grids = OccupancyGrid::from_mapping(&map);
        assert_eq!(grids.len(), map.num_macros);
        assert_eq!(grids[0].macro_id, map.first_macro());
        // Cells below the base offset stay empty in the first macro.
        assert_eq!(grids[0].owner(0, 0), None);
        assert!(grids[0].owner(0, 100).is_some());
        // Total fill equals the mapping occupancy over the same macros.
        let total_fill: f64 =
            grids.iter().map(|g| g.fill()).sum::<f64>() / grids.len() as f64;
        assert!((total_fill - map.occupancy()).abs() < 1e-9);
    }

    #[test]
    fn placed_grids_show_only_held_spans() {
        use crate::mapping::{PlacedMapping, Region};
        let spec = MacroSpec::default();
        let model = vgg9().scaled(0.04); // 108 columns
        let spans = vec![
            Region { macro_id: 2, bl_start: 100, bl_count: 50 },
            Region { macro_id: 0, bl_start: 0, bl_count: 58 },
        ];
        let placed = PlacedMapping::place_model(&model, &spec, spans).unwrap();
        let grids = OccupancyGrid::from_placed(&placed);
        assert_eq!(grids.len(), 2);
        assert_eq!((grids[0].macro_id, grids[1].macro_id), (0, 2));
        // Cells outside the held spans stay empty.
        assert!(grids[1].owner(0, 99).is_none());
        assert!(grids[1].owner(0, 100).is_some());
        assert!(grids[0].owner(0, 58).is_none());
        // Total occupied cells equal the placement's used cells.
        let cells: usize = grids
            .iter()
            .map(|g| (g.fill() * (g.wordlines * g.bitlines) as f64).round() as usize)
            .sum();
        assert_eq!(cells, placed.used_cells());
    }

    #[test]
    fn per_layer_cells_sum_to_params_share() {
        let map = pack_model(&vgg9(), &MacroSpec::default());
        let grids = OccupancyGrid::from_mapping(&map);
        let mut per_layer = vec![0usize; 8];
        for g in &grids {
            for (l, c) in g.per_layer_cells() {
                per_layer[l] += c;
            }
        }
        // Each layer's occupied cells = c_in·k²·c_out = its params.
        let m = vgg9();
        for (l, cells) in per_layer.iter().enumerate() {
            assert_eq!(*cells, m.layers[l].params(), "layer {l}");
        }
    }
}
