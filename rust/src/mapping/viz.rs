//! Rendering of occupancy grids — regenerates Figs. 12–13.
//!
//! Two backends: a binary PPM (P6) image writer (one pixel per cell,
//! macros tiled left-to-right) and a down-sampled ASCII rendering for
//! terminals. Layer colors follow a fixed 12-color palette, empty cells
//! are white — matching the look of the paper's figures.

use std::io::Write;
use std::path::Path;

use super::occupancy::OccupancyGrid;
use super::placed::PlacedMapping;

/// Distinct layer palette (RGB).
const PALETTE: [[u8; 3]; 12] = [
    [230, 25, 75],   // red
    [60, 180, 75],   // green
    [0, 130, 200],   // blue
    [245, 130, 48],  // orange
    [145, 30, 180],  // purple
    [70, 240, 240],  // cyan
    [240, 50, 230],  // magenta
    [210, 245, 60],  // lime
    [250, 190, 190], // pink
    [0, 128, 128],   // teal
    [170, 110, 40],  // brown
    [128, 128, 0],   // olive
];

fn color(layer: Option<usize>) -> [u8; 3] {
    match layer {
        None => [255, 255, 255],
        Some(l) => PALETTE[l % PALETTE.len()],
    }
}

/// Write a P6 PPM with macros tiled horizontally, 2px gutters.
pub fn render_ppm(grids: &[OccupancyGrid], path: &Path) -> anyhow::Result<()> {
    anyhow::ensure!(!grids.is_empty(), "no grids to render");
    let wl = grids[0].wordlines;
    let bl = grids[0].bitlines;
    let gutter = 2usize;
    let width = grids.len() * bl + (grids.len() - 1) * gutter;
    let height = wl;
    let mut img = vec![40u8; width * height * 3]; // dark gutter
    for (gi, g) in grids.iter().enumerate() {
        let x0 = gi * (bl + gutter);
        for r in 0..wl {
            for c in 0..bl {
                let px = ((r * width) + x0 + c) * 3;
                img[px..px + 3].copy_from_slice(&color(g.owner(r, c)));
            }
        }
    }
    let mut f = std::fs::File::create(path)?;
    write!(f, "P6\n{width} {height}\n255\n")?;
    f.write_all(&img)?;
    Ok(())
}

/// ASCII rendering: each macro down-sampled to `cols × rows` characters;
/// the dominant layer in each block picks the glyph (`A`–`Z`, `.` empty).
pub fn render_ascii(grids: &[OccupancyGrid], cols: usize, rows: usize) -> String {
    let mut out = String::new();
    for g in grids {
        out.push_str(&format!(
            "macro {:>2}  (fill {:5.1}%)\n",
            g.macro_id,
            g.fill() * 100.0
        ));
        let rstep = (g.wordlines / rows.max(1)).max(1);
        let cstep = (g.bitlines / cols.max(1)).max(1);
        for rb in (0..g.wordlines).step_by(rstep) {
            out.push_str("  ");
            for cb in (0..g.bitlines).step_by(cstep) {
                // Majority owner in the block.
                let mut counts = std::collections::BTreeMap::new();
                for r in rb..(rb + rstep).min(g.wordlines) {
                    for c in cb..(cb + cstep).min(g.bitlines) {
                        *counts.entry(g.owner(r, c)).or_insert(0usize) += 1;
                    }
                }
                let (owner, _) = counts
                    .into_iter()
                    .max_by_key(|&(_, n)| n)
                    .unwrap_or((None, 0));
                out.push(match owner {
                    None => '.',
                    Some(l) => (b'A' + (l % 26) as u8) as char,
                });
            }
            out.push('\n');
        }
    }
    out
}

/// Span-aware ASCII rendering of a multi-span placement: one header line
/// per span (logical range → physical location), then the per-macro
/// occupancy grids with cells outside the held spans left empty — the
/// fragmentation a churned co-resident pool produces, made visible.
pub fn render_placed_ascii(placed: &PlacedMapping, cols: usize, rows: usize) -> String {
    let mut out = String::new();
    for (i, (r, range)) in placed.span_ranges().enumerate() {
        out.push_str(&format!(
            "span {i}: logical [{}, {}) -> macro {} BL [{}, {})\n",
            range.start,
            range.end,
            r.macro_id,
            r.bl_start,
            r.bl_end()
        ));
    }
    out.push_str(&render_ascii(&OccupancyGrid::from_placed(placed), cols, rows));
    out
}

/// Per-layer legend lines for the ASCII rendering.
pub fn legend(num_layers: usize) -> String {
    (0..num_layers)
        .map(|l| format!("  {} = layer {}", (b'A' + (l % 26) as u8) as char, l + 1))
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vgg9;
    use crate::config::MacroSpec;
    use crate::mapping::{pack_model, OccupancyGrid};

    #[test]
    fn ppm_writes_valid_header_and_size() {
        let map = pack_model(&vgg9().scaled(0.1), &MacroSpec::default());
        let grids = OccupancyGrid::from_mapping(&map);
        let path = std::env::temp_dir().join("cim_adapt_viz_test.ppm");
        render_ppm(&grids, &path).unwrap();
        let data = std::fs::read(&path).unwrap();
        assert!(data.starts_with(b"P6\n"));
        // Parse header dims and check payload size.
        let header = String::from_utf8_lossy(&data[..40]).to_string();
        let mut it = header.split_whitespace();
        it.next(); // P6
        let w: usize = it.next().unwrap().parse().unwrap();
        let h: usize = it.next().unwrap().parse().unwrap();
        assert_eq!(h, 256);
        assert!(w >= 256);
        assert!(data.len() > w * h); // header + payload
    }

    #[test]
    fn ascii_contains_layers_and_fill() {
        let map = pack_model(&vgg9().scaled(0.1), &MacroSpec::default());
        let grids = OccupancyGrid::from_mapping(&map);
        let s = render_ascii(&grids, 32, 8);
        assert!(s.contains("macro  0"));
        assert!(s.contains('A'), "layer 1 glyph present:\n{s}");
        assert!(s.contains("fill"));
    }

    #[test]
    fn placed_ascii_lists_spans_and_macros() {
        use crate::mapping::{PlacedMapping, Region};
        let placed = PlacedMapping::place_model(
            &vgg9().scaled(0.04),
            &MacroSpec::default(),
            vec![
                Region { macro_id: 1, bl_start: 128, bl_count: 100 },
                Region { macro_id: 0, bl_start: 0, bl_count: 8 },
            ],
        )
        .unwrap();
        let s = render_placed_ascii(&placed, 32, 4);
        assert!(s.contains("span 0: logical [0, 100) -> macro 1 BL [128, 228)"), "{s}");
        assert!(s.contains("span 1: logical [100, 108) -> macro 0 BL [0, 8)"), "{s}");
        assert!(s.contains("macro  0") && s.contains("macro  1"));
    }

    #[test]
    fn legend_lists_layers() {
        let s = legend(3);
        assert!(s.contains("A = layer 1"));
        assert!(s.contains("C = layer 3"));
    }
}
