//! Weight → macro mapping (paper Fig. 3) and occupancy visualisation
//! (paper Figs. 12–13).
//!
//! The packer lays a model's convolution weights out over a sequence of
//! physical macros: every layer contributes `segments × c_out` bitline
//! columns (segment-major), each column holding up to
//! `channels_per_bl · k²` weight rows. Columns are assigned to global
//! bitline indices in layer order, spilling into additional macros every
//! `bitlines` columns — exactly the allocation the analytic cost model
//! charges for.
//!
//! [`region`] adds the fractional-macro placement unit: a [`Region`] is a
//! `(macro_id, bl_start, bl_count)` span and [`RegionAllocator`] manages
//! per-macro free-region lists, so the fleet can co-locate two models on
//! one macro's columns. *Where* an allocation lands is chosen by a
//! pluggable [`FitPolicy`] (first/best/worst/buddy/affinity built-ins,
//! selectable via [`FitPolicyKind`]). [`pack_model_at`] produces the
//! matching layout for a packing that starts mid-macro, and [`placed`]
//! generalizes it to N spans: a [`PlacedMapping`] lays the model's
//! logical column sequence across an ordered list of disjoint regions —
//! the representation a fragmented fleet placement materializes onto the
//! digital twin, and the thing [`PlacedMapping::relocate`] rewrites when
//! the fleet's compactor moves resident spans.

pub mod occupancy;
pub mod packer;
pub mod placed;
pub mod region;
pub mod viz;

pub use occupancy::OccupancyGrid;
pub use packer::{pack_model, pack_model_at, ColumnAssignment, LayerMapping, ModelMapping};
pub use placed::{PlacedMapping, PlacedRun};
pub use region::{
    AffinityFit, BestFit, BuddyFit, FirstFit, FitHints, FitPolicy, FitPolicyKind, Region,
    RegionAllocator, WorstFit,
};
pub use viz::{render_ascii, render_placed_ascii, render_ppm};
