//! Weight → macro mapping (paper Fig. 3) and occupancy visualisation
//! (paper Figs. 12–13).
//!
//! The packer lays a model's convolution weights out over a sequence of
//! physical macros: every layer contributes `segments × c_out` bitline
//! columns (segment-major), each column holding up to
//! `channels_per_bl · k²` weight rows. Columns are assigned to global
//! bitline indices in layer order, spilling into additional macros every
//! `bitlines` columns — exactly the allocation the analytic cost model
//! charges for.

pub mod occupancy;
pub mod packer;
pub mod viz;

pub use occupancy::OccupancyGrid;
pub use packer::{pack_model, ColumnAssignment, LayerMapping, ModelMapping};
pub use viz::{render_ascii, render_ppm};
