//! The bitline packer.

use crate::arch::ModelArch;
use crate::config::MacroSpec;
use crate::latency::{layer_cost, LayerCost};
use crate::util::ceil_div;

/// Where one (layer, segment, filter) column landed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ColumnAssignment {
    /// Layer index in `ModelArch::layers`.
    pub layer: usize,
    /// Wordline segment within the layer.
    pub segment: usize,
    /// Filter (output channel) within the layer.
    pub filter: usize,
    /// Global bitline index across the macro sequence.
    pub global_bl: usize,
    /// Physical macro hosting the column.
    pub macro_id: usize,
    /// Bitline local to that macro.
    pub local_bl: usize,
    /// Occupied rows in this column (≤ wordlines).
    pub rows: usize,
}

/// One layer's slice of the global bitline space.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerMapping {
    /// Layer index in `ModelArch::layers`.
    pub layer: usize,
    /// First global bitline of the layer.
    pub bl_start: usize,
    /// Columns (= segments · c_out).
    pub bl_count: usize,
    /// Wordline segments the input channels split into.
    pub segments: usize,
    /// Filters (output channels) per segment.
    pub c_out: usize,
    /// Rows used by each segment's columns (last segment may be ragged).
    pub rows_per_segment: Vec<usize>,
    /// The layer's analytic cost breakdown.
    pub cost: LayerCost,
}

impl LayerMapping {
    /// Global bitline of (segment, filter) — segment-major layout.
    pub fn column(&self, segment: usize, filter: usize) -> usize {
        debug_assert!(segment < self.segments && filter < self.c_out);
        self.bl_start + segment * self.c_out + filter
    }
}

/// The whole model mapped onto a macro sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMapping {
    /// Macro geometry the model was packed against.
    pub spec: MacroSpec,
    /// Per-layer slices, in layer order.
    pub layers: Vec<LayerMapping>,
    /// Total bitline columns the model occupies.
    pub total_bls: usize,
    /// Macros the packing touches (≥ 1 even for an off-aligned base).
    pub num_macros: usize,
    /// Global bitline the packing starts at. `pack_model` packs at 0; a
    /// non-zero base starts mid-macro on columns a co-resident tenant
    /// left free (fractional-macro placement).
    pub base_bl: usize,
}

impl ModelMapping {
    /// First macro the packing touches.
    pub fn first_macro(&self) -> usize {
        self.base_bl / self.spec.bitlines
    }

    /// Iterate every column assignment (for viz / loading).
    pub fn columns(&self) -> impl Iterator<Item = ColumnAssignment> + '_ {
        let bpm = self.spec.bitlines;
        self.layers.iter().flat_map(move |lm| {
            (0..lm.segments).flat_map(move |seg| {
                (0..lm.c_out).map(move |f| {
                    let g = lm.column(seg, f);
                    ColumnAssignment {
                        layer: lm.layer,
                        segment: seg,
                        filter: f,
                        global_bl: g,
                        macro_id: g / bpm,
                        local_bl: g % bpm,
                        rows: lm.rows_per_segment[seg],
                    }
                })
            })
        })
    }

    /// Cells occupied / cells provisioned over the allocated macros.
    pub fn occupancy(&self) -> f64 {
        let used: usize = self
            .layers
            .iter()
            .map(|lm| lm.rows_per_segment.iter().sum::<usize>() * lm.c_out)
            .sum();
        let provisioned = self.num_macros * self.spec.cells();
        if provisioned == 0 {
            0.0
        } else {
            used as f64 / provisioned as f64
        }
    }

    /// Occupied cells per **logical** macro, `num_macros` entries (entry
    /// `i` is macro `first_macro() + i`).
    ///
    /// Fleet placement reuses a model's single-device packing unchanged:
    /// logical macro `i` lands verbatim on whichever physical macro the
    /// placer assigns, so this footprint is also the physical occupancy
    /// profile after placement.
    pub fn macro_footprint(&self) -> Vec<usize> {
        let first = self.first_macro();
        let mut cells = vec![0usize; self.num_macros];
        for c in self.columns() {
            cells[c.macro_id - first] += c.rows;
        }
        cells
    }

    /// Which layers have columns in the mapping's `m`-th macro — macro
    /// `first_macro() + m`, the same relative indexing as
    /// [`ModelMapping::macro_footprint`] (for scheduling/reloads).
    pub fn layers_in_macro(&self, m: usize) -> Vec<usize> {
        let lo = (self.first_macro() + m) * self.spec.bitlines;
        let hi = lo + self.spec.bitlines;
        self.layers
            .iter()
            .filter(|lm| lm.bl_start < hi && lm.bl_start + lm.bl_count > lo)
            .map(|lm| lm.layer)
            .collect()
    }
}

/// Pack a model's conv layers into a macro sequence (Fig. 3 layout).
pub fn pack_model(model: &ModelArch, spec: &MacroSpec) -> ModelMapping {
    pack_model_at(model, spec, 0)
}

/// Pack starting at an arbitrary global bitline `base_bl`.
///
/// With `base_bl % bitlines != 0`, the first layer's columns land
/// mid-macro — the layout region-granular placement produces when a model
/// occupies the spare columns of a macro another tenant already uses.
/// `total_bls` stays base-independent; `num_macros` counts the macros the
/// span actually touches (an off-aligned base can touch one more).
pub fn pack_model_at(model: &ModelArch, spec: &MacroSpec, base_bl: usize) -> ModelMapping {
    let mut layers = Vec::with_capacity(model.layers.len());
    let mut next_bl = base_bl;
    for (i, l) in model.layers.iter().enumerate() {
        let cost = layer_cost(l, spec);
        let cpb = spec.channels_per_bl(l.kernel);
        let k2 = l.kernel * l.kernel;
        let rows_per_segment: Vec<usize> = (0..cost.segments)
            .map(|s| {
                let ch = cpb.min(l.c_in - s * cpb);
                ch * k2
            })
            .collect();
        layers.push(LayerMapping {
            layer: i,
            bl_start: next_bl,
            bl_count: cost.bls,
            segments: cost.segments,
            c_out: l.c_out,
            rows_per_segment,
            cost,
        });
        next_bl += cost.bls;
    }
    let first_macro = base_bl / spec.bitlines;
    ModelMapping {
        spec: *spec,
        layers,
        total_bls: next_bl - base_bl,
        num_macros: ceil_div(next_bl.max(base_bl + 1), spec.bitlines) - first_macro,
        base_bl,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{vgg9, vgg16};
    use crate::latency::model_cost;

    fn spec() -> MacroSpec {
        MacroSpec::default()
    }

    #[test]
    fn packing_matches_cost_model() {
        for m in [vgg9(), vgg16()] {
            let map = pack_model(&m, &spec());
            let cost = model_cost(&m, &spec());
            assert_eq!(map.total_bls, cost.bls);
            assert_eq!(map.num_macros, cost.macros_needed(&spec()));
        }
    }

    #[test]
    fn columns_are_contiguous_and_disjoint() {
        let map = pack_model(&vgg9(), &spec());
        let mut seen = vec![false; map.total_bls];
        for c in map.columns() {
            assert!(!seen[c.global_bl], "bitline {} assigned twice", c.global_bl);
            seen[c.global_bl] = true;
            assert_eq!(c.macro_id, c.global_bl / 256);
            assert_eq!(c.local_bl, c.global_bl % 256);
            assert!(c.rows <= 256);
        }
        assert!(seen.iter().all(|&s| s), "every allocated bitline used");
    }

    #[test]
    fn ragged_last_segment_rows() {
        // VGG9 layer 2: c_in=64 → segments of 28, 28, 8 channels.
        let map = pack_model(&vgg9(), &spec());
        let lm = &map.layers[2]; // c_in = 128 → 28·4 + 16: segs 28,28,28,28,16
        assert_eq!(lm.segments, 5);
        assert_eq!(lm.rows_per_segment, vec![252, 252, 252, 252, 144]);
    }

    #[test]
    fn stem_layer_uses_27_rows() {
        let map = pack_model(&vgg9(), &spec());
        assert_eq!(map.layers[0].rows_per_segment, vec![27]);
    }

    #[test]
    fn occupancy_in_sane_range() {
        let map = pack_model(&vgg9(), &spec());
        let occ = map.occupancy();
        // ≤ 252/256 packing ceiling; > 0.9 for the dense baseline.
        assert!(occ > 0.90 && occ < 0.985, "occ={occ}");
    }

    #[test]
    fn layers_in_macro_partition() {
        let map = pack_model(&vgg9(), &spec());
        // First macro hosts the early layers; layer 0 only in macro 0.
        assert!(map.layers_in_macro(0).contains(&0));
        let last = map.num_macros - 1;
        assert!(map.layers_in_macro(last).contains(&7));
        // Every layer appears in at least one macro.
        let mut covered = vec![false; 8];
        for m in 0..map.num_macros {
            for l in map.layers_in_macro(m) {
                covered[l] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn macro_footprint_sums_to_used_cells() {
        let map = pack_model(&vgg9(), &spec());
        let fp = map.macro_footprint();
        assert_eq!(fp.len(), map.num_macros);
        let used: usize = map
            .layers
            .iter()
            .map(|lm| lm.rows_per_segment.iter().sum::<usize>() * lm.c_out)
            .sum();
        assert_eq!(fp.iter().sum::<usize>(), used);
        // No macro exceeds its provisioned cells.
        assert!(fp.iter().all(|&c| c <= spec().cells()));
    }

    #[test]
    fn pack_at_offset_shifts_into_macro() {
        let base = pack_model(&vgg9(), &spec());
        let off = pack_model_at(&vgg9(), &spec(), 100);
        assert_eq!(off.base_bl, 100);
        assert_eq!(off.total_bls, base.total_bls, "footprint is base-independent");
        assert_eq!(off.first_macro(), 0);
        // The first column starts mid-macro at local bitline 100.
        let first = off.columns().next().unwrap();
        assert_eq!(first.global_bl, 100);
        assert_eq!(first.macro_id, 0);
        assert_eq!(first.local_bl, 100);
        // An off-aligned base can touch one extra macro, never more.
        assert!(off.num_macros == base.num_macros || off.num_macros == base.num_macros + 1);
        // Columns stay contiguous and disjoint from the base upward.
        let mut seen = vec![false; off.total_bls];
        for c in off.columns() {
            assert!(c.global_bl >= 100 && c.global_bl < 100 + off.total_bls);
            assert!(!seen[c.global_bl - 100]);
            seen[c.global_bl - 100] = true;
            assert_eq!(c.macro_id, c.global_bl / 256);
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn pack_at_aligned_offset_translates_macros() {
        let base = pack_model(&vgg9(), &spec());
        let off = pack_model_at(&vgg9(), &spec(), 2 * 256);
        assert_eq!(off.num_macros, base.num_macros);
        assert_eq!(off.first_macro(), 2);
        assert_eq!(off.macro_footprint(), base.macro_footprint());
        // Relative indexing agrees across the per-macro accessors.
        for m in 0..base.num_macros {
            assert_eq!(off.layers_in_macro(m), base.layers_in_macro(m));
        }
        for (a, b) in base.columns().zip(off.columns()) {
            assert_eq!(b.global_bl, a.global_bl + 512);
            assert_eq!(b.macro_id, a.macro_id + 2);
            assert_eq!(b.local_bl, a.local_bl);
            assert_eq!(b.rows, a.rows);
        }
    }

    #[test]
    fn segment_major_column_layout() {
        let map = pack_model(&vgg9(), &spec());
        let lm = &map.layers[1];
        assert_eq!(lm.column(0, 0), lm.bl_start);
        assert_eq!(lm.column(0, 1), lm.bl_start + 1);
        assert_eq!(lm.column(1, 0), lm.bl_start + lm.c_out);
    }
}
