//! Typed configuration for the whole stack, loadable from JSON files and
//! overridable from the CLI.
//!
//! The hierarchy mirrors the deployment: a [`MacroSpec`] describes the CIM
//! hardware (Fig. 1), [`MorphConfig`] drives Stage 1, [`QuantConfig`]
//! drives Stage 2, and [`ServeConfig`] parameterizes the L3 coordinator.

use std::collections::BTreeMap;
use std::path::Path;

use crate::fleet::{EvictionPolicy, QosSpec, SchedMode};
use crate::mapping::FitPolicyKind;
use crate::util::json::Json;

/// Physical description of one CIM macro (paper Fig. 1: 256×256 array,
/// 4-bit cells, 4-bit DAC input, 64 rotating 5-bit ADCs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MacroSpec {
    /// Concurrently activatable wordlines (array rows).
    pub wordlines: usize,
    /// Bitlines per macro (array columns).
    pub bitlines: usize,
    /// Bits stored per weight cell.
    pub weight_bits: u32,
    /// DAC (activation input) precision in bits.
    pub dac_bits: u32,
    /// ADC (partial-sum output) precision in bits.
    pub adc_bits: u32,
    /// Number of physical ADCs (bitlines/adcs are muxed, Fig. 2).
    pub num_adcs: usize,
    /// Cycles to load one **full** macro of weights (the paper's 256-cycle
    /// row-broadcast figure). Partial loads are charged proportionally to
    /// the columns written — `ceil(cols · load_cycles_per_macro /
    /// bitlines)`, see `latency::region_reload_cycles` — the column-serial
    /// write model that makes fractional-macro hot-swaps cheaper than
    /// whole-macro ones; a full-width load reduces to this figure exactly.
    pub load_cycles_per_macro: usize,
}

impl Default for MacroSpec {
    fn default() -> Self {
        MacroSpec {
            wordlines: 256,
            bitlines: 256,
            weight_bits: 4,
            dac_bits: 4,
            adc_bits: 5,
            num_adcs: 64,
            load_cycles_per_macro: 256,
        }
    }
}

impl MacroSpec {
    /// Max input channels one bitline column accommodates for a k×k kernel
    /// (paper Eq. 5): `floor(wordlines / k²)`; 28 for 3×3 at 256 WL.
    pub fn channels_per_bl(&self, kernel: usize) -> usize {
        self.wordlines / (kernel * kernel)
    }

    /// Signed weight range for the cell precision: `±(2^(n-1)-1)`.
    pub fn weight_qrange(&self) -> (i32, i32) {
        let q = (1i32 << (self.weight_bits - 1)) - 1;
        (-q, q)
    }

    /// Signed ADC clipping range `±(2^(n-1)-1)` (paper Eq. 7).
    pub fn adc_qrange(&self) -> (i32, i32) {
        let q = (1i32 << (self.adc_bits - 1)) - 1;
        (-q, q)
    }

    /// Unsigned activation range for the DAC precision: `[0, 2^n - 1]`.
    pub fn dac_range(&self) -> (i32, i32) {
        (0, (1i32 << self.dac_bits) - 1)
    }

    /// Total weight cells in one macro.
    pub fn cells(&self) -> usize {
        self.wordlines * self.bitlines
    }

    /// Machine-readable form (config files).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("wordlines", self.wordlines)
            .with("bitlines", self.bitlines)
            .with("weight_bits", self.weight_bits as usize)
            .with("dac_bits", self.dac_bits as usize)
            .with("adc_bits", self.adc_bits as usize)
            .with("num_adcs", self.num_adcs)
            .with("load_cycles_per_macro", self.load_cycles_per_macro)
    }

    /// Parse from JSON; missing fields fall back to the defaults.
    pub fn from_json(j: &Json) -> MacroSpec {
        let d = MacroSpec::default();
        MacroSpec {
            wordlines: j.get("wordlines").as_usize().unwrap_or(d.wordlines),
            bitlines: j.get("bitlines").as_usize().unwrap_or(d.bitlines),
            weight_bits: j
                .get("weight_bits")
                .as_usize()
                .map(|v| v as u32)
                .unwrap_or(d.weight_bits),
            dac_bits: j
                .get("dac_bits")
                .as_usize()
                .map(|v| v as u32)
                .unwrap_or(d.dac_bits),
            adc_bits: j
                .get("adc_bits")
                .as_usize()
                .map(|v| v as u32)
                .unwrap_or(d.adc_bits),
            num_adcs: j.get("num_adcs").as_usize().unwrap_or(d.num_adcs),
            load_cycles_per_macro: j
                .get("load_cycles_per_macro")
                .as_usize()
                .unwrap_or(d.load_cycles_per_macro),
        }
    }
}

/// Stage-1 (CIM-aware morphing) parameters (§II-C, §III-A).
#[derive(Debug, Clone, PartialEq)]
pub struct MorphConfig {
    /// Target bitline budget (`target_bl` in Eq. 4).
    pub target_bl: usize,
    /// Regularization weight λ of Eq. 1 (paper sweeps 3e-8..5e-8).
    pub lambda: f64,
    /// BN-γ magnitude below which a filter is pruned.
    pub gamma_threshold: f64,
    /// Morphing rounds (paper: converges in ~3).
    pub rounds: usize,
    /// Expansion-ratio search step (paper: 0.001).
    pub ratio_step: f64,
}

impl Default for MorphConfig {
    fn default() -> Self {
        MorphConfig {
            target_bl: 4096,
            lambda: 5e-8,
            gamma_threshold: 1e-2,
            rounds: 3,
            ratio_step: 0.001,
        }
    }
}

impl MorphConfig {
    /// Machine-readable form (config files).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("target_bl", self.target_bl)
            .with("lambda", self.lambda)
            .with("gamma_threshold", self.gamma_threshold)
            .with("rounds", self.rounds)
            .with("ratio_step", self.ratio_step)
    }

    /// Parse from JSON; missing fields fall back to the defaults.
    pub fn from_json(j: &Json) -> MorphConfig {
        let d = MorphConfig::default();
        MorphConfig {
            target_bl: j.get("target_bl").as_usize().unwrap_or(d.target_bl),
            lambda: j.get("lambda").as_f64().unwrap_or(d.lambda),
            gamma_threshold: j
                .get("gamma_threshold")
                .as_f64()
                .unwrap_or(d.gamma_threshold),
            rounds: j.get("rounds").as_usize().unwrap_or(d.rounds),
            ratio_step: j.get("ratio_step").as_f64().unwrap_or(d.ratio_step),
        }
    }
}

/// Stage-2 (ADC-aware learned scaling) parameters (§II-D).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantConfig {
    /// Approximate `S_W·S_ADC` by the nearest power of two (digital shift).
    pub pow2_scale: bool,
    /// Phase-1 epochs (weight quantization).
    pub p1_epochs: usize,
    /// Phase-1 learning rate.
    pub p1_lr: f64,
    /// Phase-2 epochs (partial-sum quantization; S_W frozen).
    pub p2_epochs: usize,
    /// Phase-2 learning rate.
    pub p2_lr: f64,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            pow2_scale: false,
            p1_epochs: 100,
            p1_lr: 1e-3,
            p2_epochs: 300,
            p2_lr: 1e-2,
        }
    }
}

impl QuantConfig {
    /// Machine-readable form (config files).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("pow2_scale", self.pow2_scale)
            .with("p1_epochs", self.p1_epochs)
            .with("p1_lr", self.p1_lr)
            .with("p2_epochs", self.p2_epochs)
            .with("p2_lr", self.p2_lr)
    }

    /// Parse from JSON; missing fields fall back to the defaults.
    pub fn from_json(j: &Json) -> QuantConfig {
        let d = QuantConfig::default();
        QuantConfig {
            pow2_scale: j.get("pow2_scale").as_bool().unwrap_or(d.pow2_scale),
            p1_epochs: j.get("p1_epochs").as_usize().unwrap_or(d.p1_epochs),
            p1_lr: j.get("p1_lr").as_f64().unwrap_or(d.p1_lr),
            p2_epochs: j.get("p2_epochs").as_usize().unwrap_or(d.p2_epochs),
            p2_lr: j.get("p2_lr").as_f64().unwrap_or(d.p2_lr),
        }
    }
}

/// L3 serving runtime parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Max batch the coordinator forms before dispatch.
    pub max_batch: usize,
    /// Batch formation timeout (µs): dispatch a partial batch after this.
    pub batch_timeout_us: u64,
    /// Worker threads executing compiled models.
    pub workers: usize,
    /// Bounded queue depth; beyond this, requests are rejected (backpressure).
    pub queue_depth: usize,
    /// Number of physical macros available to the scheduler.
    pub num_macros: usize,
    /// Clock frequency used to convert cycle counts to wall time (MHz).
    pub clock_mhz: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            batch_timeout_us: 2000,
            workers: 2,
            queue_depth: 256,
            num_macros: 16,
            clock_mhz: 200.0,
        }
    }
}

impl ServeConfig {
    /// Machine-readable form (config files).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("max_batch", self.max_batch)
            .with("batch_timeout_us", self.batch_timeout_us)
            .with("workers", self.workers)
            .with("queue_depth", self.queue_depth)
            .with("num_macros", self.num_macros)
            .with("clock_mhz", self.clock_mhz)
    }

    /// Parse from JSON; missing fields fall back to the defaults.
    pub fn from_json(j: &Json) -> ServeConfig {
        let d = ServeConfig::default();
        ServeConfig {
            max_batch: j.get("max_batch").as_usize().unwrap_or(d.max_batch),
            batch_timeout_us: j
                .get("batch_timeout_us")
                .as_usize()
                .map(|v| v as u64)
                .unwrap_or(d.batch_timeout_us),
            workers: j.get("workers").as_usize().unwrap_or(d.workers),
            queue_depth: j.get("queue_depth").as_usize().unwrap_or(d.queue_depth),
            num_macros: j.get("num_macros").as_usize().unwrap_or(d.num_macros),
            clock_mhz: j.get("clock_mhz").as_f64().unwrap_or(d.clock_mhz),
        }
    }
}

/// How the fleet executes inference over its placements.
///
/// * `Analytic` — the original shortcut: placements are *accounted*
///   (reload cycles, per-macro stats) but batches classify via the
///   deterministic sim rule; no weights ever move.
/// * `Twin` — placements are *materialized*: the fleet owns a pool of
///   real [`CimMacro`](crate::cim::CimMacro)s, every hot-swap streams the
///   tenant's quantized weight columns into them via `load_columns`
///   (charging the same per-region reload cycles the analytic ledger
///   records), and inference runs through the macro datapath — DAC
///   quantization, per-segment passes, ADC clipping, adder-tree scaling.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecutionMode {
    /// Placements are accounted but inference uses the analytic
    /// classifier shortcut; no weights move.
    #[default]
    Analytic,
    /// Placements are materialized on simulated macros and inference
    /// runs through the macro datapath.
    Twin,
}

impl ExecutionMode {
    /// Stable config/CLI name.
    pub fn as_str(&self) -> &'static str {
        match self {
            ExecutionMode::Analytic => "analytic",
            ExecutionMode::Twin => "twin",
        }
    }

    /// Parse a config/CLI name (see [`ExecutionMode::as_str`]).
    pub fn parse(s: &str) -> Option<ExecutionMode> {
        match s {
            "analytic" => Some(ExecutionMode::Analytic),
            "twin" => Some(ExecutionMode::Twin),
            _ => None,
        }
    }
}

/// Loop ordering of the twin's full-spatial dataflow engine
/// ([`crate::fleet::dataflow`]) — *which* order the engine walks output
/// positions, kernel taps and input activations in, and therefore how
/// much activation-buffer traffic one inference charges on the
/// buffer-traffic ledger.
///
/// All three variants execute the **identical pass set** (the compute
/// numerics and cycle charges are loop-order invariant — one macro pass
/// per output position × input segment × contiguous placed run); they
/// differ only in how often an input activation must be re-fetched from
/// the activation buffer, per the loop-ordering analysis of the
/// minimal-buffer-traffic CIM dataflow paper (arxiv 2508.14375):
///
/// * `PixelFirst` — the naive full-spatial order: for every output
///   pixel, fetch its whole `c_in·k²` receptive field. Every overlap
///   between adjacent windows is re-read (`out_px · c_in · k²` reads).
/// * `SpatialFirst` — row-stationary: an input row is held while every
///   output row that consumes it is produced, so horizontal overlap is
///   reused and each input activation is fetched once per *distinct
///   output row* that reads it (≈ k× fewer reads).
/// * `TapReuse` — the buffer-minimal order: each input activation is
///   fetched exactly once and reused across all its kernel taps and
///   overlapping windows (`c_in · in_px` reads — the paper's minimal
///   traffic bound).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DataflowKind {
    /// Naive full-spatial: re-fetch the full receptive field per output
    /// pixel (`out_px · c_in · k²` activation reads per layer).
    PixelFirst,
    /// Row-stationary: one fetch per (input activation, consuming output
    /// row) pair — horizontal tap reuse only.
    SpatialFirst,
    /// Buffer-minimal: one fetch per input activation, reused across all
    /// taps and windows (the default).
    #[default]
    TapReuse,
}

impl DataflowKind {
    /// Every variant, in schema order (the bench's per-variant arms).
    pub const ALL: [DataflowKind; 3] = [
        DataflowKind::PixelFirst,
        DataflowKind::SpatialFirst,
        DataflowKind::TapReuse,
    ];

    /// Stable config/CLI name.
    pub fn as_str(&self) -> &'static str {
        match self {
            DataflowKind::PixelFirst => "pixel-first",
            DataflowKind::SpatialFirst => "spatial-first",
            DataflowKind::TapReuse => "tap-reuse",
        }
    }

    /// Parse a config/CLI name (see [`DataflowKind::as_str`]).
    pub fn parse(s: &str) -> Option<DataflowKind> {
        DataflowKind::ALL.iter().copied().find(|k| k.as_str() == s)
    }
}

/// Fleet-level (multi-tenant) serving parameters: a pool of `num_macros`
/// physical CIM macro arrays shared by every registered model.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetConfig {
    /// Physical macros in the pool.
    pub num_macros: usize,
    /// Max per-model batch the fleet dispatcher forms.
    pub max_batch: usize,
    /// Per-model batch formation timeout (µs).
    pub batch_timeout_us: u64,
    /// Bounded fleet queue depth (backpressure beyond this).
    pub queue_depth: usize,
    /// Eviction policy when aggregate demand exceeds the pool.
    pub policy: EvictionPolicy,
    /// Fit policy choosing *where* region-granular allocations land
    /// (first/best/worst/buddy/affinity; `cim-adapt fleet --fit`).
    pub fit: FitPolicyKind,
    /// Fractional-macro co-residency: place models at bitline-region
    /// granularity so two tenants can share one macro's spare columns.
    /// Off = the degenerate whole-macro placement (region = full macro).
    pub coresident: bool,
    /// Content-addressed cross-tenant weight dedup (`cim-adapt fleet
    /// --dedup`): identical packed columns across tenants map to one
    /// resident copy with a refcount; a hot-swap only reloads the
    /// tenant's *delta* columns and shared spans are pinned against
    /// eviction while any holder is resident. Implies co-resident
    /// (region-granular) placement and materialized weight columns.
    pub dedup: bool,
    /// Online-defrag trigger (`cim-adapt fleet --defrag`): when > 0 and
    /// a hot-swap is imminent on the resident path, the fleet compacts
    /// the pool first if its fragmentation score exceeds this threshold.
    /// 0 disables; migration traffic is charged on its own ledger.
    pub defrag_threshold: f64,
    /// Whether placements run on the simulated macros ([`ExecutionMode`]).
    pub execution: ExecutionMode,
    /// Loop ordering of the twin's full-spatial dataflow engine
    /// ([`DataflowKind`]; `cim-adapt fleet --dataflow`). Decides the
    /// activation-buffer traffic charged per inference; compute cycles
    /// are loop-order invariant.
    pub dataflow: DataflowKind,
    /// Dispatch discipline: the QoS-aware dispatcher (default) or the
    /// strict-arrival-order FIFO baseline (`cim-adapt fleet --sched`).
    pub sched: SchedMode,
    /// Admission-control budget in device cycles (0 = disabled): a
    /// request whose pass cycles alone exceed this is rejected at
    /// submit; a queued batch whose projected reload + pass cycles
    /// exceed it is deferred behind resident tenants (bounded by the
    /// anti-starvation terms; see [`crate::fleet::qos`]).
    pub admit_budget_cycles: u64,
    /// Aging window in device cycles for the QoS dispatcher (0 = no
    /// aging): a queued head gains one priority level per window waited,
    /// so lower classes are delayed, never starved.
    pub qos_aging_cycles: u64,
    /// Per-tenant QoS contracts applied at registration, keyed by model
    /// name; unlisted tenants get the permissive default spec
    /// (`Interactive`, unlimited, no deadline — pinned registrations
    /// default to the `Pinned` class instead).
    pub qos: BTreeMap<String, QosSpec>,
    /// Independent pools behind the consistent-hash router
    /// (`cim-adapt fleet --pools`). 1 = the classic single-pool fleet;
    /// above 1 each pool owns `num_macros` macros and tenants hash to
    /// pools via [`crate::fleet::HashRing`]
    /// ([`crate::fleet::ShardedFleet`]).
    pub pools: usize,
    /// Inter-pool link cost in device cycles per transferred bitline
    /// column (`cim-adapt fleet --link-cost`): a cross-pool migration of
    /// a `w`-column tenant charges
    /// `ceil(w / transfer_compression) · link_cost` on the shard-level
    /// transfer ledger.
    pub link_cost: u64,
    /// Compression factor applied to cross-pool transfers (≥ 1.0;
    /// columns cross the link compressed, per the collaborative-CIM
    /// charged-transfer model of arxiv 2309.11048). 1.0 = raw columns.
    pub transfer_compression: f64,
    /// Pool-level shed trigger (0 = disabled): when a pool's pressure —
    /// registered resident demand over its capacity — exceeds this on
    /// the serve path, the sharded router migrates the pool's hottest
    /// migratable tenant to the coldest pool instead of letting the
    /// evictor thrash reloads.
    pub shed_threshold: f64,
    /// Clock frequency for cycle → wall-time conversion (MHz).
    pub clock_mhz: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            num_macros: 4,
            max_batch: 8,
            batch_timeout_us: 2000,
            queue_depth: 1024,
            policy: EvictionPolicy::Lru,
            fit: FitPolicyKind::FirstFit,
            coresident: false,
            dedup: false,
            defrag_threshold: 0.0,
            execution: ExecutionMode::Analytic,
            dataflow: DataflowKind::TapReuse,
            sched: SchedMode::Qos,
            admit_budget_cycles: 0,
            qos_aging_cycles: 50_000,
            qos: BTreeMap::new(),
            pools: 1,
            link_cost: 8,
            transfer_compression: 1.0,
            shed_threshold: 0.0,
            clock_mhz: 200.0,
        }
    }
}

impl FleetConfig {
    /// Machine-readable form (config files).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("num_macros", self.num_macros)
            .with("max_batch", self.max_batch)
            .with("batch_timeout_us", self.batch_timeout_us)
            .with("queue_depth", self.queue_depth)
            .with("policy", self.policy.as_str())
            .with("fit", self.fit.as_str())
            .with("coresident", self.coresident)
            .with("dedup", self.dedup)
            .with("defrag_threshold", self.defrag_threshold)
            .with("execution", self.execution.as_str())
            .with("dataflow", self.dataflow.as_str())
            .with("sched", self.sched.as_str())
            .with("admit_budget_cycles", self.admit_budget_cycles)
            .with("qos_aging_cycles", self.qos_aging_cycles)
            .with(
                "qos",
                self.qos
                    .iter()
                    .fold(Json::obj(), |j, (name, spec)| j.with(name.as_str(), spec.to_json())),
            )
            .with("pools", self.pools)
            .with("link_cost", self.link_cost)
            .with("transfer_compression", self.transfer_compression)
            .with("shed_threshold", self.shed_threshold)
            .with("clock_mhz", self.clock_mhz)
    }

    /// Parse from JSON; missing fields fall back to the defaults.
    pub fn from_json(j: &Json) -> FleetConfig {
        let d = FleetConfig::default();
        FleetConfig {
            num_macros: j.get("num_macros").as_usize().unwrap_or(d.num_macros),
            max_batch: j.get("max_batch").as_usize().unwrap_or(d.max_batch),
            batch_timeout_us: j
                .get("batch_timeout_us")
                .as_usize()
                .map(|v| v as u64)
                .unwrap_or(d.batch_timeout_us),
            queue_depth: j.get("queue_depth").as_usize().unwrap_or(d.queue_depth),
            policy: j
                .get("policy")
                .as_str()
                .and_then(EvictionPolicy::parse)
                .unwrap_or(d.policy),
            fit: j
                .get("fit")
                .as_str()
                .and_then(FitPolicyKind::parse)
                .unwrap_or(d.fit),
            coresident: j.get("coresident").as_bool().unwrap_or(d.coresident),
            dedup: j.get("dedup").as_bool().unwrap_or(d.dedup),
            defrag_threshold: j
                .get("defrag_threshold")
                .as_f64()
                .unwrap_or(d.defrag_threshold),
            execution: j
                .get("execution")
                .as_str()
                .and_then(ExecutionMode::parse)
                .unwrap_or(d.execution),
            dataflow: j
                .get("dataflow")
                .as_str()
                .and_then(DataflowKind::parse)
                .unwrap_or(d.dataflow),
            sched: j
                .get("sched")
                .as_str()
                .and_then(SchedMode::parse)
                .unwrap_or(d.sched),
            admit_budget_cycles: j
                .get("admit_budget_cycles")
                .as_usize()
                .map(|v| v as u64)
                .unwrap_or(d.admit_budget_cycles),
            qos_aging_cycles: j
                .get("qos_aging_cycles")
                .as_usize()
                .map(|v| v as u64)
                .unwrap_or(d.qos_aging_cycles),
            qos: j
                .get("qos")
                .as_obj()
                .map(|m| {
                    m.iter()
                        .map(|(name, spec)| (name.clone(), QosSpec::from_json(spec)))
                        .collect()
                })
                .unwrap_or_default(),
            pools: j.get("pools").as_usize().unwrap_or(d.pools),
            link_cost: j
                .get("link_cost")
                .as_usize()
                .map(|v| v as u64)
                .unwrap_or(d.link_cost),
            transfer_compression: j
                .get("transfer_compression")
                .as_f64()
                .unwrap_or(d.transfer_compression),
            shed_threshold: j.get("shed_threshold").as_f64().unwrap_or(d.shed_threshold),
            clock_mhz: j.get("clock_mhz").as_f64().unwrap_or(d.clock_mhz),
        }
    }
}

/// Top-level config bundle.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    /// CIM hardware description (Fig. 1).
    pub macro_spec: MacroSpec,
    /// Stage-1 morphing parameters.
    pub morph: MorphConfig,
    /// Stage-2 quantization parameters.
    pub quant: QuantConfig,
    /// Single-model serving runtime parameters.
    pub serve: ServeConfig,
    /// Multi-tenant fleet parameters.
    pub fleet: FleetConfig,
}

impl Config {
    /// Machine-readable form (config files).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("macro", self.macro_spec.to_json())
            .with("morph", self.morph.to_json())
            .with("quant", self.quant.to_json())
            .with("serve", self.serve.to_json())
            .with("fleet", self.fleet.to_json())
    }

    /// Parse from JSON; missing sections fall back to the defaults.
    pub fn from_json(j: &Json) -> Config {
        Config {
            macro_spec: MacroSpec::from_json(j.get("macro")),
            morph: MorphConfig::from_json(j.get("morph")),
            quant: QuantConfig::from_json(j.get("quant")),
            serve: ServeConfig::from_json(j.get("serve")),
            fleet: FleetConfig::from_json(j.get("fleet")),
        }
    }

    /// Load from a JSON file; missing fields fall back to defaults.
    pub fn load(path: &Path) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading config {path:?}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        Ok(Config::from_json(&j))
    }

    /// Write the config as pretty-printed JSON.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_macro_matches_paper() {
        let m = MacroSpec::default();
        assert_eq!(m.wordlines, 256);
        assert_eq!(m.bitlines, 256);
        assert_eq!(m.channels_per_bl(3), 28); // paper: "up to 28 input channels"
        assert_eq!(m.weight_qrange(), (-7, 7));
        assert_eq!(m.adc_qrange(), (-15, 15));
        assert_eq!(m.dac_range(), (0, 15));
        assert_eq!(m.cells(), 65536);
    }

    #[test]
    fn json_roundtrip_full() {
        let c = Config::default();
        let j = c.to_json();
        let back = Config::from_json(&j);
        assert_eq!(back, c);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"macro": {"wordlines": 128}, "morph": {"target_bl": 512}}"#)
            .unwrap();
        let c = Config::from_json(&j);
        assert_eq!(c.macro_spec.wordlines, 128);
        assert_eq!(c.macro_spec.bitlines, 256); // default
        assert_eq!(c.morph.target_bl, 512);
        assert_eq!(c.quant, QuantConfig::default());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("cim_adapt_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        let mut c = Config::default();
        c.serve.max_batch = 32;
        c.save(&path).unwrap();
        let back = Config::load(&path).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn fleet_config_roundtrip_and_policy_parse() {
        let mut c = FleetConfig::default();
        c.num_macros = 16;
        c.policy = EvictionPolicy::CostWeighted;
        c.fit = FitPolicyKind::BestFit;
        c.coresident = true;
        c.dedup = true;
        c.defrag_threshold = 0.35;
        c.execution = ExecutionMode::Twin;
        c.dataflow = DataflowKind::PixelFirst;
        c.sched = SchedMode::Fifo;
        c.admit_budget_cycles = 12_000;
        c.qos_aging_cycles = 9_000;
        c.pools = 8;
        c.link_cost = 4;
        c.transfer_compression = 2.0;
        c.shed_threshold = 0.9;
        c.qos.insert(
            "edge".to_string(),
            QosSpec {
                class: crate::fleet::QosClass::Batch,
                rate_per_kcycle: 2,
                burst: 8,
                deadline_cycles: 4_000,
            },
        );
        let back = FleetConfig::from_json(&c.to_json());
        assert_eq!(back, c);
        // Missing knobs default to whole-macro placement, analytic
        // execution, first-fit, defrag off.
        let j = Json::parse(r#"{"num_macros": 8}"#).unwrap();
        assert!(!FleetConfig::from_json(&j).coresident);
        assert!(!FleetConfig::from_json(&j).dedup, "dedup defaults off");
        assert_eq!(FleetConfig::from_json(&j).execution, ExecutionMode::Analytic);
        assert_eq!(FleetConfig::from_json(&j).fit, FitPolicyKind::FirstFit);
        assert_eq!(FleetConfig::from_json(&j).defrag_threshold, 0.0);
        assert_eq!(FleetConfig::from_json(&j).sched, SchedMode::Qos);
        assert_eq!(FleetConfig::from_json(&j).admit_budget_cycles, 0);
        assert!(FleetConfig::from_json(&j).qos.is_empty());
        // Sharding knobs default to the single-pool fleet with the
        // shed trigger disarmed.
        assert_eq!(FleetConfig::from_json(&j).pools, 1);
        assert_eq!(FleetConfig::from_json(&j).link_cost, 8);
        assert_eq!(FleetConfig::from_json(&j).transfer_compression, 1.0);
        assert_eq!(FleetConfig::from_json(&j).shed_threshold, 0.0);
        // Unknown sched string falls back to the QoS dispatcher.
        let j = Json::parse(r#"{"sched": "mystery"}"#).unwrap();
        assert_eq!(FleetConfig::from_json(&j).sched, SchedMode::Qos);
        // Fit strings parse; unknown falls back to first-fit.
        let j = Json::parse(r#"{"fit": "best", "defrag_threshold": 0.5}"#).unwrap();
        let f = FleetConfig::from_json(&j);
        assert_eq!(f.fit, FitPolicyKind::BestFit);
        assert_eq!(f.defrag_threshold, 0.5);
        let j = Json::parse(r#"{"fit": "mystery"}"#).unwrap();
        assert_eq!(FleetConfig::from_json(&j).fit, FitPolicyKind::FirstFit);
        // Execution mode parses both ways; unknown falls back to analytic.
        let j = Json::parse(r#"{"execution": "twin"}"#).unwrap();
        assert_eq!(FleetConfig::from_json(&j).execution, ExecutionMode::Twin);
        let j = Json::parse(r#"{"execution": "mystery"}"#).unwrap();
        assert_eq!(FleetConfig::from_json(&j).execution, ExecutionMode::Analytic);
        assert_eq!(ExecutionMode::parse("analytic"), Some(ExecutionMode::Analytic));
        // Dataflow variants parse; unknown falls back to tap-reuse (the
        // buffer-minimal default).
        for k in DataflowKind::ALL {
            assert_eq!(DataflowKind::parse(k.as_str()), Some(k));
        }
        let j = Json::parse(r#"{"dataflow": "pixel-first"}"#).unwrap();
        assert_eq!(FleetConfig::from_json(&j).dataflow, DataflowKind::PixelFirst);
        let j = Json::parse(r#"{"dataflow": "mystery"}"#).unwrap();
        assert_eq!(FleetConfig::from_json(&j).dataflow, DataflowKind::TapReuse);
        // Unknown policy string falls back to the default (LRU).
        let j = Json::parse(r#"{"policy": "mystery"}"#).unwrap();
        assert_eq!(FleetConfig::from_json(&j).policy, EvictionPolicy::Lru);
        let j = Json::parse(r#"{"policy": "cost-weighted", "num_macros": 2}"#).unwrap();
        let f = FleetConfig::from_json(&j);
        assert_eq!(f.policy, EvictionPolicy::CostWeighted);
        assert_eq!(f.num_macros, 2);
    }

    #[test]
    fn channels_per_bl_other_kernels() {
        let m = MacroSpec::default();
        assert_eq!(m.channels_per_bl(1), 256);
        assert_eq!(m.channels_per_bl(5), 10);
        assert_eq!(m.channels_per_bl(7), 5);
    }
}
