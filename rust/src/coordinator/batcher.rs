//! Batch formation: collect requests up to `max_batch`, or dispatch a
//! partial batch after `batch_timeout` — the standard dynamic-batching
//! policy of serving systems, here sized against the macro's throughput.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::request::InferRequest;

/// Batch-formation policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Dispatch as soon as this many requests are queued.
    pub max_batch: usize,
    /// Dispatch a partial batch once its oldest member is this old.
    pub timeout: Duration,
}

impl BatchPolicy {
    /// A policy forming batches of up to `max_batch` requests, flushing
    /// a partial batch `timeout_us` after its oldest member arrived.
    /// `max_batch == 0` clamps to 1 (batches must be possible).
    pub fn new(max_batch: usize, timeout_us: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch: max_batch.max(1),
            timeout: Duration::from_micros(timeout_us),
        }
    }

    /// Whether a queue of `queued` requests whose head has waited
    /// `head_age` should dispatch now — the single readiness predicate
    /// shared by the single-model [`Batcher`] semantics and the fleet's
    /// QoS dispatcher (which applies it per model queue before ranking
    /// the ready candidates). `draining` forces readiness on shutdown.
    pub fn ready(&self, queued: usize, head_age: Duration, draining: bool) -> bool {
        queued >= self.max_batch || head_age >= self.timeout || draining
    }
}

/// Pulls requests from a channel and forms batches.
pub struct Batcher {
    rx: mpsc::Receiver<InferRequest>,
    policy: BatchPolicy,
}

impl Batcher {
    /// A batcher pulling from `rx` under `policy`.
    pub fn new(rx: mpsc::Receiver<InferRequest>, policy: BatchPolicy) -> Batcher {
        Batcher { rx, policy }
    }

    /// Block for the next batch. Returns `None` when the channel is closed
    /// and drained (server shutdown).
    pub fn next_batch(&self) -> Option<Vec<InferRequest>> {
        // Block for the first request.
        let first = self.rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.policy.timeout;
        while batch.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn req(id: u64) -> InferRequest {
        let (tx, _rx) = mpsc::channel();
        InferRequest {
            id,
            image: vec![0.0; 4],
            enqueued: Instant::now(),
            respond: tx,
        }
    }

    #[test]
    fn fills_to_max_batch() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(req(i)).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy::new(4, 10_000));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].id, 0);
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2.len(), 4);
        assert_eq!(batch2[0].id, 4);
    }

    #[test]
    fn partial_batch_on_timeout() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(1)).unwrap();
        tx.send(req(2)).unwrap();
        let b = Batcher::new(rx, BatchPolicy::new(8, 5_000));
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn lone_request_waits_out_the_timeout_then_dispatches() {
        // The canonical timeout path: one queued request, nothing else
        // arrives → a partial batch (size 1 < max_batch) is dispatched
        // only after `batch_timeout` has elapsed.
        let (tx, rx) = mpsc::channel();
        tx.send(req(1)).unwrap();
        let timeout_us = 5_000u64;
        let b = Batcher::new(rx, BatchPolicy::new(8, timeout_us));
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        let elapsed = t0.elapsed();
        assert_eq!(batch.len(), 1, "partial batch with fewer than max_batch");
        assert!(
            elapsed >= Duration::from_micros(timeout_us),
            "dispatched after the timeout window, elapsed {elapsed:?}"
        );
        drop(tx);
    }

    #[test]
    fn max_batch_zero_clamps_to_one() {
        // A zero max_batch would make batches impossible; the policy
        // clamps it to 1 and the batcher dispatches singletons.
        let policy = BatchPolicy::new(0, 1_000);
        assert_eq!(policy.max_batch, 1);
        let (tx, rx) = mpsc::channel();
        tx.send(req(1)).unwrap();
        tx.send(req(2)).unwrap();
        let b = Batcher::new(rx, policy);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1, "clamped policy dispatches singletons");
        assert_eq!(batch[0].id, 1);
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2.len(), 1);
        assert_eq!(batch2[0].id, 2);
    }

    #[test]
    fn ready_predicate_matches_batching_rules() {
        let p = BatchPolicy::new(4, 1_000);
        assert!(p.ready(4, Duration::ZERO, false), "full batch");
        assert!(p.ready(9, Duration::ZERO, false), "overfull batch");
        assert!(!p.ready(1, Duration::ZERO, false), "fresh partial waits");
        assert!(p.ready(1, Duration::from_micros(1_000), false), "timed out");
        assert!(p.ready(1, Duration::ZERO, true), "draining flushes");
        // Callers filter empty queues before asking; the predicate itself
        // only looks at count/age/draining, so an empty timed-out queue
        // still reads as ready.
        assert!(p.ready(0, Duration::from_secs(1), false));
    }

    #[test]
    fn none_when_closed() {
        let (tx, rx) = mpsc::channel::<InferRequest>();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy::new(4, 1000));
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn late_arrivals_join_until_deadline() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(1)).unwrap();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(2));
            let _ = tx.send(req(2));
        });
        let b = Batcher::new(rx, BatchPolicy::new(8, 50_000));
        let batch = b.next_batch().unwrap();
        handle.join().unwrap();
        assert_eq!(batch.len(), 2, "late arrival should join the batch");
    }
}
