//! Serving metrics: counters + latency distribution.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// Latency statistics over recorded samples (µs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LatencyStats {
    /// Samples recorded.
    pub count: usize,
    /// Mean latency (µs).
    pub mean_us: f64,
    /// Median latency (µs).
    pub p50_us: u64,
    /// 95th-percentile latency (µs).
    pub p95_us: u64,
    /// 99th-percentile latency (µs).
    pub p99_us: u64,
    /// Worst observed latency (µs).
    pub max_us: u64,
}

impl LatencyStats {
    fn from_samples(mut xs: Vec<u64>) -> LatencyStats {
        if xs.is_empty() {
            return LatencyStats::default();
        }
        xs.sort_unstable();
        let n = xs.len();
        let pick = |q: f64| xs[((n as f64 * q) as usize).min(n - 1)];
        LatencyStats {
            count: n,
            mean_us: xs.iter().sum::<u64>() as f64 / n as f64,
            p50_us: pick(0.50),
            p95_us: pick(0.95),
            p99_us: pick(0.99),
            max_us: *xs.last().unwrap(),
        }
    }
}

/// Point-in-time view of the server's counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// Requests that passed the submit-side backpressure check. The
    /// fleet's QoS admission control may still reject some of these
    /// before they reach a queue (they then also count in `rejected`).
    pub submitted: u64,
    /// Requests answered.
    pub completed: u64,
    /// Requests refused (backpressure, admission control, failures).
    pub rejected: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Mean dispatched batch size.
    pub mean_batch: f64,
    /// Total device-model cycles charged (compute + reloads).
    pub device_cycles: u64,
    /// Weight reload events charged.
    pub weight_reloads: u64,
    /// Models evicted to make room for dispatched batches (fleet serving;
    /// always 0 on the single-model path).
    pub evictions: u64,
    /// Wall-clock latency distribution of completed requests.
    pub latency: LatencyStats,
    /// Completed requests per wall-clock second.
    pub throughput_rps: f64,
    /// Wall-clock seconds since the collector started.
    pub elapsed_s: f64,
}

impl MetricsSnapshot {
    /// Machine-readable form for `BENCH_*.json` summaries and dashboards.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("submitted", self.submitted)
            .with("completed", self.completed)
            .with("rejected", self.rejected)
            .with("batches", self.batches)
            .with("mean_batch", self.mean_batch)
            .with("device_cycles", self.device_cycles)
            .with("weight_reloads", self.weight_reloads)
            .with("evictions", self.evictions)
            .with("throughput_rps", self.throughput_rps)
            .with("elapsed_s", self.elapsed_s)
            .with(
                "latency_us",
                Json::obj()
                    .with("count", self.latency.count)
                    .with("mean", self.latency.mean_us)
                    .with("p50", self.latency.p50_us)
                    .with("p95", self.latency.p95_us)
                    .with("p99", self.latency.p99_us)
                    .with("max", self.latency.max_us),
            )
    }
}

struct Inner {
    submitted: u64,
    completed: u64,
    rejected: u64,
    batches: u64,
    batch_total: u64,
    device_cycles: u64,
    weight_reloads: u64,
    evictions: u64,
    /// Most recent latency samples, oldest at the front — a `VecDeque`
    /// so the 100k-sample cap evicts in O(1) (a `Vec::remove(0)` here
    /// made every completion past the cap an O(n) shift).
    latencies_us: VecDeque<u64>,
    started: Instant,
}

/// Latency samples retained for percentile computation; completions
/// beyond this evict the oldest sample.
const LATENCY_SAMPLE_CAP: usize = 100_000;

/// Thread-safe metrics collector shared across workers.
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            inner: Mutex::new(Inner {
                submitted: 0,
                completed: 0,
                rejected: 0,
                batches: 0,
                batch_total: 0,
                device_cycles: 0,
                weight_reloads: 0,
                evictions: 0,
                latencies_us: VecDeque::with_capacity(4096),
                started: Instant::now(),
            }),
        }
    }
}

impl Metrics {
    /// A fresh collector (clock starts now).
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Count an accepted submission.
    pub fn on_submit(&self) {
        self.inner.lock().unwrap().submitted += 1;
    }

    /// Count a refused request (backpressure, admission, failure).
    pub fn on_reject(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// Record one dispatched batch's size, device cycles, reload events
    /// and evictions.
    pub fn on_batch(&self, batch_size: usize, device_cycles: u64, reloads: u64, evictions: u64) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batch_total += batch_size as u64;
        g.device_cycles += device_cycles;
        g.weight_reloads += reloads;
        g.evictions += evictions;
    }

    /// Record a completed request's wall-clock latency.
    pub fn on_complete(&self, latency_us: u64) {
        let mut g = self.inner.lock().unwrap();
        g.completed += 1;
        // Cap memory: keep the most recent samples only.
        if g.latencies_us.len() >= LATENCY_SAMPLE_CAP {
            g.latencies_us.pop_front();
        }
        g.latencies_us.push_back(latency_us);
    }

    /// Point-in-time copy of every counter (percentiles computed here).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let elapsed = g.started.elapsed().as_secs_f64();
        MetricsSnapshot {
            submitted: g.submitted,
            completed: g.completed,
            rejected: g.rejected,
            batches: g.batches,
            mean_batch: if g.batches > 0 {
                g.batch_total as f64 / g.batches as f64
            } else {
                0.0
            },
            device_cycles: g.device_cycles,
            weight_reloads: g.weight_reloads,
            evictions: g.evictions,
            latency: LatencyStats::from_samples(g.latencies_us.iter().copied().collect()),
            throughput_rps: if elapsed > 0.0 {
                g.completed as f64 / elapsed
            } else {
                0.0
            },
            elapsed_s: elapsed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.on_submit();
        }
        m.on_reject();
        m.on_batch(4, 1000, 2, 1);
        m.on_batch(8, 2000, 0, 0);
        for i in 0..12u64 {
            m.on_complete(100 + i);
        }
        let s = m.snapshot();
        assert_eq!(s.submitted, 10);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_batch, 6.0);
        assert_eq!(s.device_cycles, 3000);
        assert_eq!(s.weight_reloads, 2);
        assert_eq!(s.evictions, 1);
        assert_eq!(s.latency.count, 12);
        assert!(s.latency.p50_us >= 100);
        assert!(s.latency.max_us == 111);
    }

    #[test]
    fn latency_cap_keeps_most_recent_samples() {
        // Regression for the O(n) `Vec::remove(0)` cap: push past the
        // 100k bound and check both the count cap and that the evicted
        // samples are the OLDEST (the minimum retained value moves up).
        let m = Metrics::new();
        let extra = 2_048u64;
        for i in 0..(LATENCY_SAMPLE_CAP as u64 + extra) {
            m.on_complete(i);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, LATENCY_SAMPLE_CAP as u64 + extra);
        assert_eq!(s.latency.count, LATENCY_SAMPLE_CAP);
        assert_eq!(s.latency.max_us, LATENCY_SAMPLE_CAP as u64 + extra - 1);
        // Oldest `extra` samples (0..extra) were evicted, so the mean of
        // the retained window is the midpoint of [extra, cap+extra).
        let expect_mean = (extra as f64 + (LATENCY_SAMPLE_CAP as u64 + extra - 1) as f64) / 2.0;
        assert!((s.latency.mean_us - expect_mean).abs() < 1e-6);
    }

    #[test]
    fn empty_latency_stats() {
        let s = LatencyStats::from_samples(vec![]);
        assert_eq!(s.count, 0);
        assert_eq!(s.p99_us, 0);
    }

    #[test]
    fn percentiles_ordered() {
        let s = LatencyStats::from_samples((0..1000).collect());
        assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us && s.p99_us <= s.max_us);
    }

    #[test]
    fn snapshot_serializes_to_json() {
        let m = Metrics::new();
        m.on_submit();
        m.on_batch(2, 500, 1, 3);
        m.on_complete(120);
        m.on_complete(140);
        let j = m.snapshot().to_json();
        assert_eq!(j.get("submitted").as_usize(), Some(1));
        assert_eq!(j.get("weight_reloads").as_usize(), Some(1));
        assert_eq!(j.get("evictions").as_usize(), Some(3));
        assert_eq!(j.at(&["latency_us", "count"]).as_usize(), Some(2));
        // Round-trips through the parser.
        let back = Json::parse(&j.pretty()).unwrap();
        assert_eq!(back.get("device_cycles").as_usize(), Some(500));
    }
}
