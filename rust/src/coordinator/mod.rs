//! Layer-3 edge-serving coordinator.
//!
//! The deployment story the paper's title promises: the adapted model,
//! AOT-compiled to a PJRT executable, served on an edge device whose
//! accelerator is the CIM macro array. Rust owns the whole request path:
//!
//! ```text
//! submit → bounded queue → batcher (size/timeout policy) → worker pool
//!        → PJRT execute (the XLA-compiled quantized model)
//!        → macro scheduler (cycle-accurate CIM cost: reloads + passes)
//!        → response + metrics
//! ```
//!
//! Two execution backends share the scheduler:
//! * [`server::EdgeServer`] — real inference through [`crate::runtime`],
//! * the same server in **sim-only** mode (no artifacts needed) where the
//!   digital twin provides deterministic per-batch latency; used by the
//!   serving benches and tests.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::{LatencyStats, Metrics, MetricsSnapshot};
pub use request::{InferRequest, InferResponse, RequestId, Ticket};
pub use scheduler::{InferencePlan, MacroScheduler};
pub use server::{EdgeServer, ServerHandle};
