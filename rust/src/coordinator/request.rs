//! Request/response types flowing through the coordinator.

use std::sync::mpsc;
use std::time::Instant;

/// Monotonic request identifier.
pub type RequestId = u64;

/// One inference request: a CHW image plus response plumbing.
pub struct InferRequest {
    /// Monotonic id assigned at submit.
    pub id: RequestId,
    /// Flattened CHW image pixels.
    pub image: Vec<f32>,
    /// Wall-clock submit time (batch-timeout + latency accounting).
    pub enqueued: Instant,
    /// Channel the response is delivered on.
    pub respond: mpsc::Sender<InferResponse>,
}

/// The answer delivered to the submitter.
#[derive(Debug, Clone, PartialEq)]
pub struct InferResponse {
    /// Id of the request this answers.
    pub id: RequestId,
    /// Argmax class.
    pub class: usize,
    /// Raw logits (num_classes).
    pub logits: Vec<f32>,
    /// Wall-clock time from submit to completion (µs).
    pub latency_us: u64,
    /// Device-model latency: CIM cycles this request's share of the batch
    /// consumed (compute + amortized weight reloads).
    pub device_cycles: u64,
    /// Batch size this request was served in.
    pub batch_size: usize,
}

/// Handle returned by `submit`: await the response on it.
pub struct Ticket {
    /// Id of the submitted request.
    pub id: RequestId,
    /// Channel the response arrives on.
    pub rx: mpsc::Receiver<InferResponse>,
}

impl Ticket {
    /// Block until the response arrives.
    pub fn wait(self) -> anyhow::Result<InferResponse> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped request {}", self.id))
    }

    /// Wait with a timeout.
    pub fn wait_timeout(self, d: std::time::Duration) -> anyhow::Result<InferResponse> {
        self.rx
            .recv_timeout(d)
            .map_err(|e| anyhow::anyhow!("request {}: {e}", self.id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticket_roundtrip() {
        let (tx, rx) = mpsc::channel();
        let t = Ticket { id: 7, rx };
        let resp = InferResponse {
            id: 7,
            class: 3,
            logits: vec![0.0; 10],
            latency_us: 42,
            device_cycles: 100,
            batch_size: 4,
        };
        tx.send(resp.clone()).unwrap();
        assert_eq!(t.wait().unwrap(), resp);
    }

    #[test]
    fn ticket_errors_when_sender_dropped() {
        let (tx, rx) = mpsc::channel::<InferResponse>();
        drop(tx);
        let t = Ticket { id: 1, rx };
        assert!(t.wait().is_err());
    }
}
