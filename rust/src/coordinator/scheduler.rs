//! CIM macro scheduler: plans a model's layer executions over a limited
//! set of physical macros, accounting for weight reloads — the latency
//! effect the paper's Stage-1 morphing exists to minimize.
//!
//! The mapping (`mapping::pack_model`) assigns every layer's columns to a
//! sequence of *logical* macros. The device has `num_macros` *physical*
//! macros; if the model needs more, logical macros are paged in on demand
//! (LRU), each page-in costing a full weight-load (256 cycles). The
//! per-inference compute cycles come from the calibrated cost model, so a
//! morphed model's plan reproduces the Tables III–V latency columns.

use std::collections::VecDeque;

use crate::config::MacroSpec;
use crate::latency::ModelCost;
use crate::mapping::ModelMapping;

/// The static execution plan for one inference.
#[derive(Debug, Clone, PartialEq)]
pub struct InferencePlan {
    /// Compute cycles for one image through all conv layers.
    pub compute_cycles: u64,
    /// Logical macros the model occupies.
    pub logical_macros: usize,
    /// Physical macros available.
    pub physical_macros: usize,
    /// Weight-load cycles on a cold start (every logical macro loaded).
    pub cold_load_cycles: u64,
    /// Reload events incurred by ONE inference when the model does not
    /// fit residently (steady state, LRU paging over the layer sequence).
    pub reloads_per_inference: u64,
    /// Cycles of those reloads.
    pub reload_cycles_per_inference: u64,
}

impl InferencePlan {
    /// Steady-state device cycles for a batch of `n` images: reloads are
    /// paid once per pass through the layer sequence (weights stay put
    /// while the batch streams), compute scales with n.
    pub fn batch_cycles(&self, n: usize) -> u64 {
        self.compute_cycles * n as u64 + self.reload_cycles_per_inference
    }

    /// Device wall time for a batch at `clock_mhz`.
    pub fn batch_seconds(&self, n: usize, clock_mhz: f64) -> f64 {
        self.batch_cycles(n) as f64 / (clock_mhz * 1e6)
    }
}

/// Scheduler over a model mapping.
pub struct MacroScheduler {
    /// The derived static execution plan.
    pub plan: InferencePlan,
}

impl MacroScheduler {
    /// Build the plan for `mapping` + `cost` on a device with
    /// `num_macros` physical macros.
    pub fn new(
        mapping: &ModelMapping,
        cost: &ModelCost,
        spec: &MacroSpec,
        num_macros: usize,
    ) -> MacroScheduler {
        let logical = mapping.num_macros;
        let physical = num_macros.max(1);
        let load_per_macro = spec.load_cycles_per_macro as u64;

        // Simulate one inference's macro-access sequence under LRU to
        // count steady-state page-ins. Layers execute in order; each
        // touches its logical macros in ascending order.
        let mut reloads = 0u64;
        if logical > physical {
            let mut lru: VecDeque<usize> = VecDeque::new();
            // Warm cache = the state left by the previous inference; run
            // the sequence twice and count the second pass.
            for pass in 0..2 {
                for lm in &mapping.layers {
                    let first = lm.bl_start / spec.bitlines;
                    let last = (lm.bl_start + lm.bl_count - 1) / spec.bitlines;
                    for mac in first..=last {
                        if let Some(pos) = lru.iter().position(|&m| m == mac) {
                            lru.remove(pos);
                            lru.push_back(mac);
                        } else {
                            if lru.len() == physical {
                                lru.pop_front();
                            }
                            lru.push_back(mac);
                            if pass == 1 {
                                reloads += 1;
                            }
                        }
                    }
                }
            }
        }
        MacroScheduler {
            plan: InferencePlan {
                compute_cycles: cost.computing_latency as u64,
                logical_macros: logical,
                physical_macros: physical,
                cold_load_cycles: logical as u64 * load_per_macro,
                reloads_per_inference: reloads,
                reload_cycles_per_inference: reloads * load_per_macro,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vgg9;
    use crate::latency::model_cost;
    use crate::mapping::pack_model;

    fn setup(scale: f64, num_macros: usize) -> InferencePlan {
        let spec = MacroSpec::default();
        let arch = vgg9().scaled(scale);
        let mapping = pack_model(&arch, &spec);
        let cost = model_cost(&arch, &spec);
        MacroScheduler::new(&mapping, &cost, &spec, num_macros).plan
    }

    #[test]
    fn resident_model_never_reloads() {
        let plan = setup(0.125, 16);
        assert!(plan.logical_macros <= 16);
        assert_eq!(plan.reloads_per_inference, 0);
        assert_eq!(plan.batch_cycles(4), plan.compute_cycles * 4);
    }

    #[test]
    fn oversubscribed_model_pages() {
        // Full VGG9 needs 151 macros; with 8 physical, every inference
        // reloads every macro (working set >> cache).
        let plan = setup(1.0, 8);
        assert_eq!(plan.logical_macros, 151);
        assert_eq!(plan.reloads_per_inference, 151);
        assert_eq!(
            plan.reload_cycles_per_inference,
            151 * 256
        );
    }

    #[test]
    fn paper_load_latency_reproduced_when_single_macro() {
        // The paper's "Load Weight Latency" = cold load of all logical
        // macros: ceil(38592/256)·256 = 38656 for baseline VGG9.
        let plan = setup(1.0, 1);
        assert_eq!(plan.cold_load_cycles, 38_656);
        assert_eq!(plan.compute_cycles, 14_696);
    }

    #[test]
    fn batch_amortizes_reloads() {
        let plan = setup(1.0, 8);
        let per_image_b1 = plan.batch_cycles(1) as f64;
        let per_image_b8 = plan.batch_cycles(8) as f64 / 8.0;
        assert!(per_image_b8 < per_image_b1 * 0.6, "batching should amortize reloads");
    }

    #[test]
    fn more_physical_macros_never_hurt() {
        let mut prev = u64::MAX;
        for n in [1usize, 4, 16, 64, 151] {
            let plan = setup(1.0, n);
            assert!(plan.reload_cycles_per_inference <= prev);
            prev = plan.reload_cycles_per_inference;
        }
        assert_eq!(setup(1.0, 151).reloads_per_inference, 0);
    }

    #[test]
    fn batch_seconds_scales_with_clock() {
        let plan = setup(0.125, 16);
        let slow = plan.batch_seconds(1, 100.0);
        let fast = plan.batch_seconds(1, 200.0);
        assert!((slow / fast - 2.0).abs() < 1e-9);
    }
}
