//! The edge server: bounded queue → batcher → workers → PJRT/sim backend.
//!
//! The `xla` crate's PJRT client is not `Send` (it wraps `Rc` + raw
//! pointers), so the server hands each worker thread a [`Backend`]
//! *factory*: every worker constructs its own client + executables inside
//! the thread and keeps them for its lifetime. Compilation cost is paid
//! once per worker at startup; the request path never crosses a thread
//! boundary with PJRT state.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::Result;

use super::batcher::BatchPolicy;
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{InferRequest, InferResponse, Ticket};
use super::scheduler::{InferencePlan, MacroScheduler};
use crate::config::ServeConfig;
use crate::latency::model_cost;
use crate::mapping::pack_model;
use crate::runtime::{ArtifactMeta, ModelRuntime, StreamCodec};

/// Backend factory: how each worker obtains its execution engine.
#[derive(Clone)]
pub enum Backend {
    /// Compiled artifact (the production path): each worker loads the
    /// artifact into its own PJRT client.
    Pjrt {
        /// Directory holding the AOT-lowered artifacts.
        artifact_dir: PathBuf,
        /// Artifact model name (e.g. `"vgg9_edge"`).
        model: String,
    },
    /// Sim-only: classify via a trivial deterministic rule; lets serving
    /// tests/benches run without built artifacts.
    Sim {
        /// Classifier classes of the simulated head.
        num_classes: usize,
    },
}

impl Backend {
    /// Artifact metadata when applicable (validates before spawn).
    fn meta(&self) -> Result<Option<ArtifactMeta>> {
        match self {
            Backend::Pjrt { artifact_dir, model } => Ok(Some(ArtifactMeta::load(
                &artifact_dir.join(format!("{model}_meta.json")),
            )?)),
            Backend::Sim { .. } => Ok(None),
        }
    }
}

/// Per-worker instantiated engine.
enum Engine {
    Pjrt(ModelRuntime),
    Sim { num_classes: usize },
}

impl Engine {
    fn build(backend: &Backend) -> Result<Engine> {
        match backend {
            Backend::Pjrt { artifact_dir, model } => Ok(Engine::Pjrt(
                // Serving variants only: skips demo exports (pallas_b1)
                // whose compile time would stall worker startup.
                ModelRuntime::load_serving(artifact_dir, model)?,
            )),
            Backend::Sim { num_classes } => Ok(Engine::Sim {
                num_classes: *num_classes,
            }),
        }
    }

    fn num_classes(&self) -> usize {
        match self {
            Engine::Pjrt(rt) => rt.meta.num_classes,
            Engine::Sim { num_classes } => *num_classes,
        }
    }
}

/// The server: owns worker threads; submit via [`ServerHandle`].
pub struct EdgeServer;

/// Submission handle (thread-safe).
pub struct ServerHandle {
    tx: Mutex<Option<mpsc::Sender<InferRequest>>>,
    next_id: AtomicU64,
    depth: Arc<AtomicU64>,
    queue_limit: u64,
    /// Live serving counters (shared with the workers).
    pub metrics: Arc<Metrics>,
    /// The static CIM execution plan being served.
    pub plan: InferencePlan,
    workers: Mutex<Vec<thread::JoinHandle<()>>>,
    accepting: AtomicBool,
    /// Set during shutdown: workers skip the batch-timeout wait so the
    /// queue drains promptly (they still serve everything queued).
    draining: Arc<AtomicBool>,
    image_len: usize,
    /// Reusable wire codec behind [`ServerHandle::submit_bytes`].
    codec: Mutex<StreamCodec>,
}

impl EdgeServer {
    /// Start serving. The CIM execution plan is derived from `arch` (the
    /// morphed architecture being served) and `spec` (the macro).
    pub fn start(
        cfg: &ServeConfig,
        backend: Backend,
        arch: &crate::arch::ModelArch,
        spec: &crate::config::MacroSpec,
    ) -> Arc<ServerHandle> {
        let mapping = pack_model(arch, spec);
        let cost = model_cost(arch, spec);
        let plan = MacroScheduler::new(&mapping, &cost, spec, cfg.num_macros).plan;
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel::<InferRequest>();
        let rx = Arc::new(Mutex::new(rx));
        let depth = Arc::new(AtomicU64::new(0));
        let draining = Arc::new(AtomicBool::new(false));
        let image_len = match backend.meta() {
            Ok(Some(meta)) => meta.image_len(),
            _ => 3 * 32 * 32,
        };

        let mut workers = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel::<bool>();
        for wid in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let backend = backend.clone();
            let metrics = Arc::clone(&metrics);
            let depth = Arc::clone(&depth);
            let draining = Arc::clone(&draining);
            let policy = BatchPolicy::new(cfg.max_batch, cfg.batch_timeout_us);
            let plan = plan.clone();
            let ready_tx = ready_tx.clone();
            workers.push(
                thread::Builder::new()
                    .name(format!("cim-serve-{wid}"))
                    .spawn(move || {
                        // Engine construction (PJRT compile) happens before
                        // readiness is signalled, so start() returns a warm
                        // server and first-request latency excludes
                        // compilation (§Perf iteration 4).
                        let engine = match Engine::build(&backend) {
                            Ok(e) => e,
                            Err(e) => {
                                log::error!("worker {wid}: backend init failed: {e:#}");
                                let _ = ready_tx.send(false);
                                return;
                            }
                        };
                        let _ = ready_tx.send(true);
                        worker_loop(rx, engine, metrics, depth, draining, policy, plan)
                    })
                    .expect("spawn worker"),
            );
        }
        drop(ready_tx);
        // Wait for every worker's engine (failures are logged; a server
        // whose workers all failed still returns — submits then error).
        for _ in 0..workers.len() {
            let _ = ready_rx.recv();
        }
        Arc::new(ServerHandle {
            tx: Mutex::new(Some(tx)),
            next_id: AtomicU64::new(1),
            depth,
            queue_limit: cfg.queue_depth as u64,
            metrics,
            plan,
            workers: Mutex::new(workers),
            accepting: AtomicBool::new(true),
            draining,
            image_len,
            codec: Mutex::new(StreamCodec::new()),
        })
    }
}

fn worker_loop(
    rx: Arc<Mutex<mpsc::Receiver<InferRequest>>>,
    engine: Engine,
    metrics: Arc<Metrics>,
    depth: Arc<AtomicU64>,
    draining: Arc<AtomicBool>,
    policy: BatchPolicy,
    plan: InferencePlan,
) {
    loop {
        // Form a batch while holding the receiver (workers alternate).
        //
        // Greedy-then-wait policy: drain whatever is already queued
        // without blocking (lone requests dispatch immediately instead of
        // eating the batch timeout — §Perf iteration 2), and only wait
        // out the timeout when a batch has started forming under load.
        let batch = {
            let guard = rx.lock().unwrap();
            let first = match guard.recv() {
                Ok(r) => r,
                Err(_) => return,
            };
            let mut batch = vec![first];
            while batch.len() < policy.max_batch {
                match guard.try_recv() {
                    Ok(r) => batch.push(r),
                    Err(_) => break,
                }
            }
            // During shutdown the flag short-circuits the batch-timeout
            // wait — checked between batches, never mid-pass, so every
            // queued request is still served before the worker exits.
            if batch.len() > 1 && batch.len() < policy.max_batch && !draining.load(Ordering::Acquire)
            {
                // Load present: give concurrent arrivals the window.
                let deadline = Instant::now() + policy.timeout;
                while batch.len() < policy.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match guard.recv_timeout(deadline - now) {
                        Ok(r) => batch.push(r),
                        Err(_) => break,
                    }
                }
            }
            batch
        };
        depth.fetch_sub(batch.len() as u64, Ordering::AcqRel);
        let n = batch.len();

        let (classes, logits_all) = match execute(&engine, &batch) {
            Ok(x) => x,
            Err(e) => {
                log::error!("batch execution failed: {e:#}");
                continue; // requests drop; Ticket::wait errors out.
            }
        };

        let device_cycles = plan.batch_cycles(n);
        metrics.on_batch(n, device_cycles, plan.reloads_per_inference, 0);
        let per_req_cycles = device_cycles / n as u64;
        let k = engine.num_classes();
        for (i, req) in batch.into_iter().enumerate() {
            let latency_us = req.enqueued.elapsed().as_micros() as u64;
            metrics.on_complete(latency_us);
            let _ = req.respond.send(InferResponse {
                id: req.id,
                class: classes[i],
                logits: logits_all[i * k..(i + 1) * k].to_vec(),
                latency_us,
                device_cycles: per_req_cycles,
                batch_size: n,
            });
        }
    }
}

fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// The Sim backend's deterministic classifier: per-class sums over image
/// chunks. Shared with the fleet server so single-model and multi-tenant
/// serving agree on sim outputs for identical inputs.
pub fn sim_logits(image: &[f32], num_classes: usize) -> Vec<f32> {
    let n = image.len().max(1);
    let k = num_classes.max(1);
    let mut sums = vec![0.0f32; k];
    for (i, v) in image.iter().enumerate() {
        sums[(i * k / n).min(k - 1)] += v;
    }
    sums
}

/// Argmax over [`sim_logits`] (convenience for sim execution paths).
pub fn sim_classify(image: &[f32], num_classes: usize) -> (usize, Vec<f32>) {
    let logits = sim_logits(image, num_classes);
    (argmax(&logits), logits)
}

fn execute(engine: &Engine, batch: &[InferRequest]) -> Result<(Vec<usize>, Vec<f32>)> {
    match engine {
        Engine::Pjrt(rt) => {
            // Greedily cover the batch with the largest compiled variants.
            let k = rt.meta.num_classes;
            let mut classes = Vec::with_capacity(batch.len());
            let mut logits = Vec::with_capacity(batch.len() * k);
            let mut i = 0;
            while i < batch.len() {
                let remaining = batch.len() - i;
                let (variant, b) = rt
                    .best_batch_variant(remaining)
                    .ok_or_else(|| anyhow::anyhow!("no batch variant available"))?;
                let mut images = Vec::with_capacity(b * rt.meta.image_len());
                for req in &batch[i..i + b] {
                    images.extend_from_slice(&req.image);
                }
                let out = rt.infer(variant, &images)?;
                for row in out.chunks(k) {
                    classes.push(argmax(row));
                    logits.extend_from_slice(row);
                }
                i += b;
            }
            Ok((classes, logits))
        }
        Engine::Sim { num_classes } => {
            // Deterministic stand-in: per-class sums over image chunks.
            let k = *num_classes;
            let mut classes = Vec::with_capacity(batch.len());
            let mut logits = Vec::with_capacity(batch.len() * k);
            for req in batch {
                let (class, sums) = sim_classify(&req.image, k);
                classes.push(class);
                logits.extend_from_slice(&sums);
            }
            Ok((classes, logits))
        }
    }
}

impl ServerHandle {
    /// Submit an image; rejects when the queue is full (backpressure).
    pub fn submit(&self, image: Vec<f32>) -> Result<Ticket> {
        anyhow::ensure!(
            self.accepting.load(Ordering::Acquire),
            "server shutting down"
        );
        anyhow::ensure!(
            image.len() == self.image_len,
            "image must be {} floats, got {}",
            self.image_len,
            image.len()
        );
        let cur = self.depth.load(Ordering::Acquire);
        if cur >= self.queue_limit {
            self.metrics.on_reject();
            anyhow::bail!("queue full ({cur} pending)");
        }
        self.metrics.on_submit();
        self.depth.fetch_add(1, Ordering::AcqRel);
        let id = self.next_id.fetch_add(1, Ordering::AcqRel);
        let (rtx, rrx) = mpsc::channel();
        let req = InferRequest {
            id,
            image,
            enqueued: Instant::now(),
            respond: rtx,
        };
        let sent = {
            let guard = self.tx.lock().unwrap();
            match guard.as_ref() {
                Some(tx) => tx.send(req).is_ok(),
                None => false,
            }
        };
        if !sent {
            // The request never reached the queue, so no worker will
            // decrement for it — roll the accounting back here or the
            // depth counter leaks and backpressure tightens forever.
            self.depth.fetch_sub(1, Ordering::AcqRel);
            self.metrics.on_reject();
            anyhow::bail!("server stopped");
        }
        Ok(Ticket { id, rx: rrx })
    }

    /// Submit a request from its JSON wire form,
    /// `{"image": [f32; image_len]}`, decoded through the handle's
    /// reusable [`StreamCodec`] — no `Json` tree is built.
    pub fn submit_bytes(&self, bytes: &[u8]) -> Result<Ticket> {
        let image = {
            let mut codec = self.codec.lock().unwrap();
            let req = codec
                .decode_request(bytes)
                .map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
            req.take_image()
        };
        self.submit(image)
    }

    /// Stop accepting, drain every queued request, join workers, and
    /// return the final metrics.
    ///
    /// Graceful-drain contract: a `submit` that returned `Ok` has placed
    /// its request on the queue, and workers only exit once the queue is
    /// empty — so **every accepted ticket resolves**, shutdown included
    /// (`shutdown_resolves_every_accepted_ticket` is the regression
    /// test). The draining flag only skips the batch-timeout wait
    /// between batches; no pass is interrupted.
    pub fn shutdown(&self) -> MetricsSnapshot {
        self.accepting.store(false, Ordering::Release);
        self.draining.store(true, Ordering::Release);
        // Dropping the sender ends the worker loops once drained.
        *self.tx.lock().unwrap() = None;
        let mut workers = self.workers.lock().unwrap();
        for w in workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vgg9;
    use crate::config::MacroSpec;

    fn sim_server(cfg: ServeConfig) -> Arc<ServerHandle> {
        let arch = vgg9().scaled(0.125);
        EdgeServer::start(
            &cfg,
            Backend::Sim { num_classes: 10 },
            &arch,
            &MacroSpec::default(),
        )
    }

    #[test]
    fn serves_and_shuts_down() {
        let h = sim_server(ServeConfig {
            workers: 2,
            max_batch: 4,
            batch_timeout_us: 500,
            ..ServeConfig::default()
        });
        let mut tickets = Vec::new();
        for _ in 0..20 {
            tickets.push(h.submit(vec![0.5; 3072]).unwrap());
        }
        for t in tickets {
            let r = t.wait().unwrap();
            assert!(r.class < 10);
            assert!(r.batch_size >= 1);
            assert!(r.device_cycles > 0);
        }
        let m = h.shutdown();
        assert_eq!(m.completed, 20);
        assert_eq!(m.submitted, 20);
        assert!(m.mean_batch >= 1.0);
    }

    #[test]
    fn rejects_wrong_image_size() {
        let h = sim_server(ServeConfig::default());
        assert!(h.submit(vec![0.0; 5]).is_err());
        h.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Tiny queue with a single worker ⇒ a fast submitter overruns it.
        let h = sim_server(ServeConfig {
            workers: 1,
            max_batch: 1,
            batch_timeout_us: 1,
            queue_depth: 4,
            ..ServeConfig::default()
        });
        let mut rejected = 0u64;
        let mut tickets = Vec::new();
        for _ in 0..500 {
            match h.submit(vec![0.1; 3072]) {
                Ok(t) => tickets.push(t),
                Err(_) => rejected += 1,
            }
        }
        for t in tickets {
            let _ = t.wait();
        }
        let m = h.shutdown();
        assert_eq!(m.rejected, rejected);
        assert!(rejected > 0, "expected backpressure rejections");
    }

    #[test]
    fn batching_aggregates_under_load() {
        let h = sim_server(ServeConfig {
            workers: 1,
            max_batch: 8,
            batch_timeout_us: 3000,
            ..ServeConfig::default()
        });
        let tickets: Vec<_> = (0..32)
            .map(|_| h.submit(vec![0.2; 3072]).unwrap())
            .collect();
        let mut max_batch_seen = 0;
        for t in tickets {
            max_batch_seen = max_batch_seen.max(t.wait().unwrap().batch_size);
        }
        let m = h.shutdown();
        assert!(
            max_batch_seen > 1,
            "expected some batching, mean={}",
            m.mean_batch
        );
    }

    #[test]
    fn shutdown_resolves_every_accepted_ticket() {
        // Race a submitter against shutdown: whatever `submit` accepted
        // must resolve — the drain serves the whole queue before the
        // workers join, and failed sends roll their accounting back.
        let h = sim_server(ServeConfig {
            workers: 2,
            max_batch: 4,
            batch_timeout_us: 2000,
            queue_depth: 4096,
            ..ServeConfig::default()
        });
        let h2 = Arc::clone(&h);
        let submitter = thread::spawn(move || {
            let mut tickets = Vec::new();
            for _ in 0..2000 {
                match h2.submit(vec![0.3; 3072]) {
                    Ok(t) => tickets.push(t),
                    Err(_) => break, // shutdown observed
                }
            }
            tickets
        });
        std::thread::sleep(std::time::Duration::from_millis(2));
        let m = h.shutdown();
        let tickets = submitter.join().unwrap();
        let accepted = tickets.len() as u64;
        assert!(accepted > 0, "test needs at least one accepted ticket");
        for t in tickets {
            t.wait().expect("accepted ticket must resolve");
        }
        assert_eq!(m.completed, accepted);
        // Depth returned to zero: accepted requests were all consumed
        // and failed sends rolled their increment back.
        assert_eq!(h.depth.load(Ordering::Acquire), 0);
    }

    #[test]
    fn submit_bytes_round_trips_the_wire_format() {
        use crate::runtime::{ResponseView, StreamCodec};
        use crate::util::json::Json;

        let h = sim_server(ServeConfig::default());
        let img = crate::data::SynthCifar::sample(4, 9);
        let direct = h.submit(img.data.clone()).unwrap().wait().unwrap();

        let mut wire = Vec::from(&br#"{"image":["#[..]);
        for (i, v) in img.data.iter().enumerate() {
            if i > 0 {
                wire.push(b',');
            }
            wire.extend_from_slice(format!("{v}").as_bytes());
        }
        wire.extend_from_slice(b"]}");
        let resp = h.submit_bytes(&wire).unwrap().wait().unwrap();
        assert_eq!(resp.class, direct.class);
        assert_eq!(resp.logits, direct.logits);

        let mut codec = StreamCodec::new();
        let encoded = codec.encode_response(ResponseView::of(&resp));
        let tree = Json::parse(std::str::from_utf8(encoded).unwrap()).unwrap();
        assert_eq!(tree.get("class").as_usize(), Some(resp.class));
        assert_eq!(tree.get("id").as_usize(), Some(resp.id as usize));

        assert!(h.submit_bytes(b"{\"image\": [1;2]}").is_err());
        h.shutdown();
    }

    #[test]
    fn sim_classifier_is_deterministic() {
        let h = sim_server(ServeConfig::default());
        let img = crate::data::SynthCifar::sample(4, 9);
        let a = h.submit(img.data.clone()).unwrap().wait().unwrap();
        let b = h.submit(img.data).unwrap().wait().unwrap();
        assert_eq!(a.class, b.class);
        assert_eq!(a.logits, b.logits);
        h.shutdown();
    }
}
