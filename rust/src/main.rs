//! `cim-adapt` — CLI launcher for the whole stack.
//!
//! ```text
//! cim-adapt tables  [--artifacts DIR]          regenerate Tables I–VI
//! cim-adapt map     --model vgg9 --bl 512      Figs. 12/13 occupancy maps
//! cim-adapt morph   --model vgg9 --bl 4096     run the morphing flow
//! cim-adapt cost    --model vgg16              cost-model columns
//! cim-adapt serve   [--requests N]             edge-serving demo (PJRT)
//! cim-adapt inspect --model vgg9               CIM mapping details
//! ```

#![warn(missing_docs)]

use std::path::{Path, PathBuf};

use cim_adapt::arch::by_name;
use cim_adapt::config::{
    DataflowKind, ExecutionMode, FleetConfig, MacroSpec, MorphConfig, ServeConfig,
};
use cim_adapt::coordinator::server::{Backend, EdgeServer};
use cim_adapt::data::SynthCifar;
use cim_adapt::fleet::{EvictionPolicy, FleetServer, QosClass, SchedMode, ShardedFleet};
use cim_adapt::latency::{cost::allocated_usage, model_cost};
use cim_adapt::mapping::{pack_model, pack_model_at, FitPolicyKind};
use cim_adapt::morph::flow::morph_flow_synthetic;
use cim_adapt::obs::{ascii_timeline, events_from_chrome, FleetTrace};
use cim_adapt::report::{fig12_13, table1, table2, table3_4_5, table6};
use cim_adapt::runtime::ModelRuntime;
use cim_adapt::util::cli::{Args, Help};
use cim_adapt::util::commas;
use cim_adapt::util::json::Json;

fn main() -> anyhow::Result<()> {
    cim_adapt::util::logging::init();
    let args = Args::from_env();
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    match args.cmd.as_deref() {
        Some("tables") => cmd_tables(&artifacts),
        Some("map") => cmd_map(&args),
        Some("morph") => cmd_morph(&args),
        Some("cost") => cmd_cost(&args),
        Some("serve") => cmd_serve(&args, &artifacts),
        Some("fleet") => cmd_fleet(&args),
        Some("inspect") => cmd_inspect(&args),
        _ => {
            print!(
                "{}",
                Help::new("cim-adapt", "CIM-aware model adaptation for edge devices")
                    .cmd("tables", "regenerate Tables I–VI of the paper")
                    .cmd("map --model M --bl N [--out DIR]", "occupancy maps (Figs. 12–13)")
                    .cmd("morph --model M --bl N", "run the Stage-1 morphing flow")
                    .cmd("cost --model M", "analytic cost columns for a model")
                    .cmd("serve [--requests N] [--batch B]", "edge-serving demo over PJRT")
                    .cmd(
                        "fleet [--macros N] [--bl B] [--requests N] [--policy lru|cost] \
                         [--fit first|best|worst|buddy|affinity] [--coresident] [--dedup] [--twin] \
                         [--dataflow pixel-first|spatial-first|tap-reuse] \
                         [--defrag [--defrag-threshold T]] [--qos] [--sched qos|fifo] \
                         [--priority m=class,..] [--rate m=R[:BURST],..] \
                         [--deadline m=CYCLES,..] [--admit-budget N] \
                         [--trace-out FILE] [--metrics-out FILE] \
                         [--pools N [--tenants T] [--link-cost C] \
                          [--transfer-compression F] [--shed-threshold T] [--json FILE]]",
                        "multi-tenant hot-swap serving demo (--twin: run on the simulated \
                         macros; --dataflow: the twin's loop ordering — changes only the \
                         charged activation-buffer traffic, never the numerics; \
                         --defrag: compact the pool online when fragmentation \
                         crosses the threshold; --qos: demo priority classes; --priority/\
                         --rate/--deadline: per-tenant QoS contracts; --admit-budget: \
                         reject/defer dispatches whose projected reload+pass cycles \
                         exceed N; --sched fifo: the arrival-order baseline; \
                         --trace-out: write a Chrome-trace JSON of the run and audit the \
                         ledgers against it; --metrics-out: write Prometheus text metrics; \
                         --pools N: consistent-hash sharded serving across N pools of \
                         --macros each — saturated pools shed their hottest tenant over \
                         the charged inter-pool link and all five ledgers are audited; \
                         --json: write the shard snapshot as JSON)",
                    )
                    .cmd(
                        "inspect --model M [--base-bl N] [--spans m:s:c,...] [--timeline FILE]",
                        "per-layer CIM mapping details (--spans: render a multi-span \
                         placement; --timeline: render an ASCII per-macro timeline from a \
                         Chrome-trace JSON written by fleet --trace-out)",
                    )
                    .render()
            );
            Ok(())
        }
    }
}

fn cmd_tables(artifacts: &Path) -> anyhow::Result<()> {
    println!("{}\n", table1(artifacts).rendered);
    println!("{}\n", table2(artifacts).rendered);
    for m in ["vgg9", "vgg16", "resnet18"] {
        println!("{}\n", table3_4_5(m, artifacts).rendered);
    }
    println!("{}", table6(artifacts).rendered);
    Ok(())
}

fn cmd_map(args: &Args) -> anyhow::Result<()> {
    let bl = args.usize_or("bl", 512);
    let out = args.get("out").map(PathBuf::from);
    let fig = fig12_13(bl, out.as_deref())?;
    println!("{}", fig.rendered);
    if let Some(p) = fig.ppm_path {
        println!("wrote {}", p.display());
    }
    Ok(())
}

fn cmd_morph(args: &Args) -> anyhow::Result<()> {
    let model = args.str_or("model", "vgg9");
    let target = args.usize_or("bl", 4096);
    let spec = MacroSpec::default();
    let cfg = MorphConfig {
        target_bl: target,
        rounds: args.usize_or("rounds", 3),
        ..MorphConfig::default()
    };
    let arch = by_name(model)?;
    let base = model_cost(&arch, &spec);
    let out = morph_flow_synthetic(
        &arch,
        &spec,
        &cfg,
        args.f64_or("sparsity", 0.4),
        args.u64_or("seed", 11),
    );
    println!(
        "model {model}: baseline {:.3}M params, {} BLs",
        base.params as f64 / 1e6,
        commas(base.bls as u64)
    );
    for r in &out.rounds {
        println!(
            "  round {}: pruned to {:.3}M, expanded ×{:.3} → {:.3}M ({} BLs)",
            r.round + 1,
            r.pruned_params as f64 / 1e6,
            r.expansion_ratio,
            r.expanded_params as f64 / 1e6,
            commas(r.expanded_bls as u64)
        );
    }
    println!(
        "final: {:.3}M params | {} BLs | usage {:.2}% | load {} | compute {} cycles",
        out.cost.params as f64 / 1e6,
        commas(out.cost.bls as u64),
        out.macro_usage * 100.0,
        commas(out.cost.load_weight_latency as u64),
        commas(out.cost.computing_latency as u64)
    );
    println!(
        "channels: {:?}",
        out.arch.layers.iter().map(|l| l.c_out).collect::<Vec<_>>()
    );
    Ok(())
}

fn cmd_cost(args: &Args) -> anyhow::Result<()> {
    let model = args.str_or("model", "vgg9");
    let spec = MacroSpec::default();
    let arch = by_name(model)?;
    let c = model_cost(&arch, &spec);
    println!("model {model} on {}×{} macro:", spec.wordlines, spec.bitlines);
    println!("  params            {}", commas(c.params as u64));
    println!("  bitlines          {}", commas(c.bls as u64));
    println!("  macros needed     {}", c.macros_needed(&spec));
    println!("  MACs (ADC conv.)  {}", commas(c.macs as u64));
    println!("  load latency      {} cycles", commas(c.load_weight_latency as u64));
    println!("  compute latency   {} cycles", commas(c.computing_latency as u64));
    println!(
        "  psum storage      {} words ({} bits)",
        commas(c.psum_storage as u64),
        commas(c.psum_bits(&spec) as u64)
    );
    println!("  allocated usage   {:.2}%", allocated_usage(&c, &spec) * 100.0);
    println!("  per-layer:");
    for (l, lc) in arch.layers.iter().zip(&c.per_layer) {
        println!(
            "    {:<10} {:>4}→{:<4} segs {:>2}  bls {:>6}  macs {:>9}  cycles {:>7}",
            l.name, l.c_in, l.c_out, lc.segments, lc.bls, lc.macs, lc.computing_latency
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args, artifacts: &Path) -> anyhow::Result<()> {
    let n = args.usize_or("requests", 256);
    let mut cfg = ServeConfig::default();
    cfg.max_batch = args.usize_or("batch", cfg.max_batch);
    cfg.workers = args.usize_or("workers", cfg.workers);
    cfg.num_macros = args.usize_or("macros", cfg.num_macros);

    let model = args.str_or("model", "vgg9_edge");
    // Probe-load once for banner info; workers construct their own.
    let rt = ModelRuntime::load(artifacts, model)?;
    println!(
        "loaded '{model}' on {} (variants {:?}); morphed arch: {} layers, {:.3}M params",
        rt.platform(),
        rt.variants(),
        rt.meta.arch.layers.len(),
        rt.meta.arch.params() as f64 / 1e6
    );
    let arch = rt.meta.arch.clone();
    drop(rt);
    let spec = MacroSpec::default();
    let backend = Backend::Pjrt {
        artifact_dir: artifacts.to_path_buf(),
        model: model.to_string(),
    };
    let handle = EdgeServer::start(&cfg, backend, &arch, &spec);
    println!(
        "plan: {} logical macros on {} physical; reloads/inference {}",
        handle.plan.logical_macros, handle.plan.physical_macros, handle.plan.reloads_per_inference
    );
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::with_capacity(n);
    let mut correct = 0usize;
    for k in 0..n {
        let cls = k % 10;
        let img = SynthCifar::sample(cls, 7000 + k as u64);
        tickets.push((cls, handle.submit(img.data)?));
    }
    for (cls, t) in tickets {
        let r = t.wait()?;
        if r.class == cls {
            correct += 1;
        }
    }
    let elapsed = t0.elapsed();
    let m = handle.shutdown();
    println!(
        "served {n} requests in {:.2}s ({:.0} rps) | accuracy {:.1}% | mean batch {:.2} | p50 {}µs p95 {}µs p99 {}µs",
        elapsed.as_secs_f64(),
        n as f64 / elapsed.as_secs_f64(),
        correct as f64 / n as f64 * 100.0,
        m.mean_batch,
        m.latency.p50_us,
        m.latency.p95_us,
        m.latency.p99_us
    );
    println!(
        "device model: {} cycles total, {} weight reloads (= {:.2}ms @200MHz)",
        commas(m.device_cycles),
        m.weight_reloads,
        m.device_cycles as f64 / 200e6 * 1e3
    );
    Ok(())
}

/// Parse per-tenant `model=value` CSV flags (`--priority`, `--rate`,
/// `--deadline`) into the config's QoS map.
fn parse_qos_flags(args: &Args, cfg: &mut FleetConfig) -> anyhow::Result<()> {
    if let Some(list) = args.get("priority") {
        for part in list.split(',').filter(|p| !p.is_empty()) {
            let (model, class) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--priority expects model=class, got '{part}'"))?;
            let class = QosClass::parse(class).ok_or_else(|| {
                anyhow::anyhow!("--priority class must be pinned|interactive|batch, got '{class}'")
            })?;
            cfg.qos.entry(model.to_string()).or_default().class = class;
        }
    }
    if let Some(list) = args.get("rate") {
        for part in list.split(',').filter(|p| !p.is_empty()) {
            let (model, rate) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--rate expects model=R[:BURST], got '{part}'"))?;
            let (r, burst) = match rate.split_once(':') {
                Some((r, b)) => (r, b.parse::<u64>().map_err(|_| {
                    anyhow::anyhow!("--rate burst must be an integer, got '{b}'")
                })?),
                None => (rate, 0),
            };
            let r: u64 = r
                .parse()
                .map_err(|_| anyhow::anyhow!("--rate must be requests/kcycle, got '{r}'"))?;
            let spec = cfg.qos.entry(model.to_string()).or_default();
            spec.rate_per_kcycle = r;
            spec.burst = burst;
        }
    }
    if let Some(list) = args.get("deadline") {
        for part in list.split(',').filter(|p| !p.is_empty()) {
            let (model, cycles) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--deadline expects model=CYCLES, got '{part}'"))?;
            cfg.qos.entry(model.to_string()).or_default().deadline_cycles = cycles
                .parse()
                .map_err(|_| anyhow::anyhow!("--deadline must be cycles, got '{cycles}'"))?;
        }
    }
    Ok(())
}

fn cmd_fleet(args: &Args) -> anyhow::Result<()> {
    let spec = MacroSpec::default();
    let pools = args.usize_or("pools", 1);
    let mut cfg = FleetConfig {
        pools,
        link_cost: args.u64_or("link-cost", FleetConfig::default().link_cost),
        transfer_compression: args.f64_or("transfer-compression", 1.0),
        // The sharded demo arms the shed policy by default — that's the
        // behaviour `--pools` exists to show; single-pool keeps it off.
        shed_threshold: args.f64_or("shed-threshold", if pools > 1 { 0.9 } else { 0.0 }),
        num_macros: args.usize_or("macros", 4),
        max_batch: args.usize_or("batch", 8),
        policy: EvictionPolicy::parse(args.str_or("policy", "lru"))
            .ok_or_else(|| anyhow::anyhow!("--policy expects 'lru' or 'cost-weighted'"))?,
        fit: FitPolicyKind::parse(args.str_or("fit", "first")).ok_or_else(|| {
            anyhow::anyhow!("--fit expects 'first', 'best', 'worst', 'buddy' or 'affinity'")
        })?,
        coresident: args.flag("coresident"),
        dedup: args.flag("dedup"),
        defrag_threshold: if args.flag("defrag") {
            args.f64_or("defrag-threshold", 0.3)
        } else {
            0.0
        },
        execution: if args.flag("twin") {
            ExecutionMode::Twin
        } else {
            ExecutionMode::Analytic
        },
        sched: SchedMode::parse(args.str_or("sched", "qos"))
            .ok_or_else(|| anyhow::anyhow!("--sched expects 'qos' or 'fifo'"))?,
        admit_budget_cycles: args.u64_or("admit-budget", 0),
        dataflow: DataflowKind::parse(args.str_or("dataflow", "tap-reuse")).ok_or_else(|| {
            anyhow::anyhow!("--dataflow expects 'pixel-first', 'spatial-first' or 'tap-reuse'")
        })?,
        ..FleetConfig::default()
    };
    let target_bl = args.usize_or("bl", 512);
    let n = args.usize_or("requests", 300);

    // Three adapted tenants, morphed to the bitline budget so several can
    // co-reside on the pool; demand still exceeds it → hot-swaps happen.
    let models = ["vgg9", "vgg16", "resnet18"];
    if args.flag("qos") {
        // Demo mix: the first tenant is latency-critical, the rest are
        // throughput traffic — overridable per tenant via --priority.
        for (i, m) in models.iter().enumerate() {
            cfg.qos.entry(m.to_string()).or_default().class = if i == 0 {
                QosClass::Interactive
            } else {
                QosClass::Batch
            };
        }
    }
    parse_qos_flags(args, &mut cfg)?;
    if cfg.pools > 1 {
        return cmd_fleet_sharded(args, &cfg, &spec);
    }
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    // The trace bundle is only built (and the fleet only pays the
    // per-event cost) when an exporter will consume it.
    let trace = if trace_out.is_some() || metrics_out.is_some() {
        Some(FleetTrace::default())
    } else {
        None
    };
    let handle = FleetServer::start_with_trace(&cfg, &spec, trace.as_ref());
    for (i, m) in models.iter().enumerate() {
        let out = morph_flow_synthetic(
            &by_name(m)?,
            &spec,
            &MorphConfig {
                target_bl,
                ..MorphConfig::default()
            },
            0.4,
            11 + i as u64,
        );
        let macros = pack_model(&out.arch, &spec).num_macros;
        println!(
            "registered '{m}' morphed to {} BLs ({:.3}M params, {} macros)",
            commas(out.cost.bls as u64),
            out.cost.params as f64 / 1e6,
            macros
        );
        handle.register(m, out.arch, false)?;
    }
    // Under --dedup every tenant gets a fine-tuned head: same backbone
    // columns cell-for-cell, divergent classifier — the shape the
    // content-addressed store multiplies capacity on.
    let mut serve_names: Vec<String> = models.iter().map(|m| m.to_string()).collect();
    if cfg.dedup {
        for m in models {
            let head = format!("{m}-head");
            handle.register_derived(&head, m, false)?;
            println!("registered '{head}' as a derived head of '{m}' (shared backbone)");
            serve_names.push(head);
        }
    }
    println!(
        "fleet: {} macros, policy {}, fit {}, max batch {}, placement {}, execution {}{}",
        cfg.num_macros,
        cfg.policy.as_str(),
        cfg.fit.as_str(),
        cfg.max_batch,
        if cfg.dedup {
            "co-resident + content-addressed dedup"
        } else if cfg.coresident {
            "co-resident (bitline regions)"
        } else {
            "whole-macro"
        },
        cfg.execution.as_str(),
        if cfg.defrag_threshold > 0.0 {
            format!(", defrag @ {:.2}", cfg.defrag_threshold)
        } else {
            String::new()
        }
    );
    println!(
        "dispatch: {} scheduler{}{}",
        cfg.sched.as_str(),
        if cfg.admit_budget_cycles > 0 {
            format!(", admission budget {} cycles", commas(cfg.admit_budget_cycles))
        } else {
            String::new()
        },
        if cfg.qos.is_empty() {
            String::new()
        } else {
            let specs: Vec<String> = cfg
                .qos
                .iter()
                .map(|(m, s)| {
                    let mut desc = format!("{m}={}", s.class.as_str());
                    if s.rate_limited() {
                        desc.push_str(&format!(" rate {}/kcycle burst {}", s.rate_per_kcycle, s.burst));
                    }
                    if s.deadline_cycles > 0 {
                        desc.push_str(&format!(" deadline {}", s.deadline_cycles));
                    }
                    desc
                })
                .collect();
            format!(", qos [{}]", specs.join(", "))
        }
    );

    let t0 = std::time::Instant::now();
    let mut tickets = Vec::with_capacity(n);
    for k in 0..n {
        let model = &serve_names[k % serve_names.len()];
        let img = SynthCifar::sample(k % 10, 9000 + k as u64);
        tickets.push(handle.submit(model, img.data)?);
    }
    // Rate-limited / over-budget requests are rejected by admission
    // control: their tickets error out, which is the expected shape of
    // an overloaded fleet, not a failure of the demo.
    let mut served = 0usize;
    let mut refused = 0usize;
    for t in tickets {
        match t.wait() {
            Ok(_) => served += 1,
            Err(_) => refused += 1,
        }
    }
    let elapsed = t0.elapsed();
    let (m, snap) = handle.shutdown();
    println!(
        "served {served} of {n} requests ({refused} refused by admission) in {:.2}s \
         ({:.0} rps) | mean batch {:.2} | p95 {}µs",
        elapsed.as_secs_f64(),
        served as f64 / elapsed.as_secs_f64(),
        m.mean_batch,
        m.latency.p95_us
    );
    println!(
        "hot-swaps {} | evictions {} | reload cycles {} (= per-macro sum {}, per-tenant sum {})",
        snap.hot_swaps,
        snap.evictions,
        commas(snap.reload_cycles),
        commas(snap.macro_load_cycles()),
        commas(snap.tenant_load_cycles())
    );
    let frag = snap.fragmentation();
    println!(
        "compactions {} | migration cycles {} (= per-macro {}, per-tenant {}) | \
         fragmentation {:.3} ({} free regions, largest run {}, {:.2} spans/tenant)",
        snap.compactions,
        commas(snap.migration_cycles),
        commas(snap.macro_migration_cycles()),
        commas(snap.tenant_migration_cycles()),
        frag.score(),
        frag.free_regions,
        frag.largest_free_run,
        frag.mean_spans_per_tenant()
    );
    if snap.dedup_enabled {
        println!(
            "dedup: {} logical bitlines resident in {} physical ({:.2}x), {} borrowed by \
             reference | {} reload cycles avoided by sharing",
            commas(snap.dedup_logical_bls as u64),
            commas(snap.dedup_resident_bls() as u64),
            snap.dedup_ratio(),
            commas(snap.dedup_shared_bls as u64),
            commas(snap.dedup_shared_cycles)
        );
    }
    if !snap.twin_stats.is_empty() {
        println!(
            "twin: {} load cycles charged on the simulated macros ({} the analytic ledger), \
             {} executed compute cycles, {} ADC conversions",
            commas(snap.twin_load_cycles()),
            if snap.twin_load_cycles() == snap.reload_cycles {
                "exactly matching"
            } else {
                "DIVERGED from"
            },
            commas(snap.twin_stats.iter().map(|s| s.compute_cycles).sum::<u64>()),
            commas(snap.twin_stats.iter().map(|s| s.conversions).sum::<u64>())
        );
        println!(
            "buffer ({}): {} activation reads / {} writes charged ({} the twin mirror)",
            snap.dataflow.as_str(),
            commas(snap.buffer_fleet.reads),
            commas(snap.buffer_fleet.writes),
            if snap.buffer_twin == snap.buffer_fleet {
                "exactly matching"
            } else {
                "DIVERGED from"
            }
        );
    }
    println!(
        "fleet utilization {:.1}% of {} pool bitlines (occupied per macro: {:?})",
        snap.utilization() * 100.0,
        commas((snap.occupied_bls.len() * snap.bitlines_per_macro) as u64),
        snap.occupied_bls
    );
    let device = snap.aggregate();
    println!(
        "device model @ {:.0} MHz: {} busy cycles = {:.2} ms ({:.1}% spent reloading)",
        cfg.clock_mhz,
        commas(device.busy_cycles()),
        device.busy_cycles() as f64 / (cfg.clock_mhz * 1e6) * 1e3,
        device.load_cycles as f64 / device.busy_cycles().max(1) as f64 * 100.0
    );
    for (i, s) in snap.macro_stats.iter().enumerate() {
        println!(
            "  macro {i}: compute {} | load {} | reloads {}",
            commas(s.compute_cycles),
            commas(s.load_cycles),
            s.reloads
        );
    }
    for (name, s) in &snap.tenant_stats {
        println!(
            "  tenant '{name}': compute {} | load {} | reloads {}",
            commas(s.compute_cycles),
            commas(s.load_cycles),
            s.reloads
        );
    }
    if !snap.qos_stats.is_empty() {
        let totals = snap.qos_totals();
        println!(
            "qos: {} admitted | {} rejected | {} deferrals | {} queue-delay cycles | {} deadline misses",
            totals.admitted,
            totals.rejected,
            totals.deferred,
            commas(totals.queue_delay_cycles),
            totals.deadline_misses
        );
        for (name, q) in &snap.qos_stats {
            println!(
                "  qos '{name}': admitted {} | rejected {} | deferred {} | queue delay {} cycles{}",
                q.admitted,
                q.rejected,
                q.deferred,
                commas(q.queue_delay_cycles),
                if q.deadline_misses > 0 {
                    format!(" | {} deadline misses", q.deadline_misses)
                } else {
                    String::new()
                }
            );
        }
    }
    for p in &snap.resident {
        let spans: Vec<String> = p
            .regions
            .iter()
            .map(|r| format!("{}:[{},{})", r.macro_id, r.bl_start, r.bl_end()))
            .collect();
        println!(
            "  resident '{}' on macros {:?} (regions {})",
            p.model,
            p.macros(),
            spans.join(" ")
        );
    }
    if let Some(trace) = &trace {
        let report = trace.audit.lock().unwrap().verify(&snap);
        let (total, dropped) = {
            let log = trace.log.lock().unwrap();
            (log.total(), log.dropped())
        };
        println!(
            "trace: {total} events recorded ({dropped} dropped by the ring) | \
             ledger audit {} ({} checks)",
            if report.pass { "PASS" } else { "FAIL" },
            report.checks
        );
        if let Some(div) = &report.first_divergence {
            println!("  first divergence: {div}");
        }
        if let Some(path) = &trace_out {
            let tenants: Vec<String> =
                snap.tenant_stats.iter().map(|(name, _)| name.clone()).collect();
            let chrome = trace.chrome(snap.macro_stats.len(), &tenants);
            std::fs::write(path, chrome.pretty())?;
            println!("wrote Chrome trace to {}", path.display());
        }
        if let Some(path) = &metrics_out {
            std::fs::write(path, trace.prometheus(Some(report.pass)))?;
            println!("wrote Prometheus metrics to {}", path.display());
        }
        anyhow::ensure!(report.pass, "ledger audit failed: {:?}", report.first_divergence);
    }
    Ok(())
}

/// The `--pools N` arm of `cmd_fleet`: a fleet-of-fleets demo. `N`
/// independent pools sit behind the consistent-hash router; tenants
/// (scaled variants of `--model`) hash to home pools, and a saturated
/// pool sheds its hottest tenant to the coldest one over the charged
/// inter-pool link. Every pool's four ledgers and the shard's fifth
/// (transfer) ledger are audited against the trace before exit.
fn cmd_fleet_sharded(args: &Args, cfg: &FleetConfig, spec: &MacroSpec) -> anyhow::Result<()> {
    let tenants = args.usize_or("tenants", 4 * cfg.pools);
    let n = args.usize_or("requests", 300);
    let scale = args.f64_or("scale", 0.03);
    let base = by_name(args.str_or("model", "vgg9"))?;

    let mut shard = ShardedFleet::new(cfg, spec);
    // One trace bundle per pool (each audits its own four ledgers) plus
    // a shard-level bundle that sees only the MigratePool events.
    let shard_trace = FleetTrace::default();
    shard.set_trace(Some(shard_trace.sink()));
    let pool_traces: Vec<FleetTrace> =
        (0..shard.num_pools()).map(|_| FleetTrace::default()).collect();
    for (p, t) in pool_traces.iter().enumerate() {
        shard.pool_mut(p).set_trace(Some(t.sink()));
    }

    for i in 0..tenants {
        let name = format!("m{i}");
        let home = shard.register(&name, base.scaled(scale), false)?;
        log::debug!("tenant '{name}' homed on pool {home}");
    }
    println!(
        "sharded fleet: {} pools × {} macros ({} columns total) | {} tenants @ scale {:.2} | \
         link cost {} cycles/column, transfer compression {:.1}x, shed threshold {:.2}",
        cfg.pools,
        cfg.num_macros,
        commas((cfg.pools * cfg.num_macros * spec.bitlines) as u64),
        tenants,
        scale,
        cfg.link_cost,
        cfg.transfer_compression,
        cfg.shed_threshold
    );

    for k in 0..n {
        let name = format!("m{}", k % tenants);
        let img = SynthCifar::sample(k % 10, 9000 + k as u64);
        shard.serve_batch(&name, &[img.data])?;
    }

    let snap = shard.snapshot();
    for (p, ps) in snap.pools.iter().enumerate() {
        println!(
            "  pool {p}: pressure {:.2} | reload {} | migration {} | transfer-in {} | \
             evictions {} | residents {}",
            shard.pressure(p),
            commas(ps.reload_cycles),
            commas(ps.migration_cycles),
            commas(snap.pool_transfer_cycles[p]),
            ps.evictions,
            ps.resident.len()
        );
    }
    println!(
        "transfer ledger: {} cycles over {} transfers (= per-pool sum {}, per-tenant sum {}) | \
         movement total {} (reload {} + migration {} + transfer {})",
        commas(snap.transfer_cycles),
        snap.transfers,
        commas(snap.pool_transfer_cycles.iter().sum::<u64>()),
        commas(snap.tenant_transfer_cycles.iter().map(|(_, c)| c).sum::<u64>()),
        commas(snap.total_movement_cycles()),
        commas(snap.total_reload_cycles()),
        commas(snap.total_migration_cycles()),
        commas(snap.transfer_cycles)
    );

    // Five-ledger audit: each pool's four ledgers against its own event
    // stream, then the shard's transfer ledger against the MigratePool
    // stream.
    let mut pass = true;
    for (p, t) in pool_traces.iter().enumerate() {
        let report = t.audit.lock().unwrap().verify(&snap.pools[p]);
        if !report.pass {
            pass = false;
            println!("  pool {p} audit FAIL: {:?}", report.first_divergence);
        }
    }
    let transfer_report = shard_trace.audit.lock().unwrap().verify_transfers(&snap);
    if !transfer_report.pass {
        pass = false;
        println!("  transfer audit FAIL: {:?}", transfer_report.first_divergence);
    }
    println!(
        "five-ledger audit {} ({} pools × four ledgers + transfer ledger, {} transfer checks)",
        if pass { "PASS" } else { "FAIL" },
        snap.pools.len(),
        transfer_report.checks
    );
    if let Some(path) = args.get("json") {
        std::fs::write(path, snap.to_json().pretty())?;
        println!("wrote shard snapshot to {path}");
    }
    anyhow::ensure!(pass, "five-ledger audit failed");
    Ok(())
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    // --timeline renders a trace file, no model needed.
    if let Some(timeline) = args.flag_or_value("timeline") {
        let path = timeline
            .ok_or_else(|| anyhow::anyhow!("--timeline expects a Chrome-trace JSON file"))?;
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read trace file '{path}': {e}"))?;
        let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("bad trace JSON: {e:?}"))?;
        let events = events_from_chrome(&doc)?;
        print!("{}", ascii_timeline(&events, args.usize_or("width", 72)));
        return Ok(());
    }
    let model = args.str_or("model", "vgg9");
    let spec = MacroSpec::default();
    let arch = by_name(model)?;
    // --spans renders the model placed over an explicit multi-span layout
    // ("macro:bl_start:bl_count,..."), the shape a fragmented co-resident
    // fleet placement produces.
    if let Some(spans_arg) = args.get("spans") {
        let mut spans = Vec::new();
        for part in spans_arg.split(',') {
            let fields: Vec<usize> = part
                .split(':')
                .map(|f| {
                    f.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad span '{part}' (want macro:start:count)"))
                })
                .collect::<anyhow::Result<_>>()?;
            anyhow::ensure!(fields.len() == 3, "bad span '{part}' (want macro:start:count)");
            spans.push(cim_adapt::mapping::Region {
                macro_id: fields[0],
                bl_start: fields[1],
                bl_count: fields[2],
            });
        }
        let placed = cim_adapt::mapping::PlacedMapping::place_model(&arch, &spec, spans)?;
        println!(
            "model {model}: {} columns over {} spans on macros {:?} ({} occupied cells)",
            commas(placed.total_bls() as u64),
            placed.spans.len(),
            placed.macros(),
            commas(placed.used_cells() as u64)
        );
        print!("{}", cim_adapt::mapping::render_placed_ascii(&placed, 64, 8));
        return Ok(());
    }
    // --base-bl packs at a bitline offset — the layout a co-resident
    // fleet placement produces when the model starts mid-macro.
    let base_bl = args.usize_or("base-bl", 0);
    let mapping = pack_model_at(&arch, &spec, base_bl);
    println!(
        "model {model}: {} bitline columns over {} macros (base BL {}, first macro {}), occupancy {:.2}%",
        commas(mapping.total_bls as u64),
        mapping.num_macros,
        mapping.base_bl,
        mapping.first_macro(),
        mapping.occupancy() * 100.0
    );
    for lm in &mapping.layers {
        println!(
            "  layer {:>2} '{}': BL [{}, {}) — {} segments × {} filters, rows/seg {:?}",
            lm.layer,
            arch.layers[lm.layer].name,
            lm.bl_start,
            lm.bl_start + lm.bl_count,
            lm.segments,
            lm.c_out,
            lm.rows_per_segment
        );
    }
    Ok(())
}
