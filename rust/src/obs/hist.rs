//! Log-bucketed cycle histograms, aggregated per tenant and per QoS
//! class.
//!
//! The QoS scheduler's `TenantStats` keep running sums (means only);
//! the histograms here answer the tail questions — p99 queue delay,
//! worst reload burst — that sums cannot. Buckets are powers of two
//! (bucket `i ≥ 1` holds values in `[2^(i-1), 2^i)`; bucket 0 holds
//! exactly 0), so recording is two instructions and merging is
//! element-wise — deterministic, allocation-free, and cheap enough to
//! run inline as a [`TraceSink`](super::TraceSink).

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::event::{EventKind, TraceEvent};
use super::sink::TraceSink;

/// Number of histogram buckets: bucket 0 for zero, buckets 1..=64 for
/// each power-of-two magnitude of a `u64`.
pub const HIST_BUCKETS: usize = 65;

/// A fixed-shape log₂ histogram of cycle counts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CycleHistogram {
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    sum: u64,
}

impl Default for CycleHistogram {
    fn default() -> CycleHistogram {
        CycleHistogram { buckets: [0; HIST_BUCKETS], count: 0, sum: 0 }
    }
}

impl CycleHistogram {
    /// The bucket index `v` lands in: 0 for 0, else `floor(log2 v) + 1`.
    pub fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            64 - v.leading_zeros() as usize
        }
    }

    /// The largest value bucket `i` can hold (`0`, `2^i - 1`, ...,
    /// saturating at `u64::MAX`) — the Prometheus `le` bound.
    pub fn bucket_ceiling(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= 64 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    /// Record one observation.
    pub fn record(&mut self, v: u64) {
        self.buckets[Self::bucket_index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all observations (saturating).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// The raw per-bucket counts (index with [`CycleHistogram::bucket_ceiling`]).
    pub fn buckets(&self) -> &[u64; HIST_BUCKETS] {
        &self.buckets
    }

    /// Element-wise merge of another histogram into this one.
    pub fn merge(&mut self, other: &CycleHistogram) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }

    /// Upper bound of the bucket containing the `q`-quantile
    /// (`0.0 ..= 1.0`); 0 when empty. Log-bucketed, so the answer is an
    /// order-of-magnitude bound, not an exact rank.
    pub fn quantile_ceiling(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= rank {
                return Self::bucket_ceiling(i);
            }
        }
        Self::bucket_ceiling(HIST_BUCKETS - 1)
    }

    /// JSON form: count, sum, and the non-empty buckets as
    /// `{le, count}` pairs (deterministic order).
    pub fn to_json(&self) -> Json {
        let nonzero: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, b)| **b > 0)
            .map(|(i, b)| Json::obj().with("le", Self::bucket_ceiling(i)).with("count", *b))
            .collect();
        Json::obj()
            .with("count", self.count)
            .with("sum", self.sum)
            .with("buckets", nonzero)
    }
}

/// The three per-lane histograms the trace feeds: queue delay
/// (`DispatchStart`), pass/compute time (`DispatchEnd`), and reload
/// charges (`RegionReload`, analytic side only).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LaneHists {
    /// Cycles each dispatched batch waited in its queue.
    pub queue_delay: CycleHistogram,
    /// Compute cycles each served batch charged.
    pub pass: CycleHistogram,
    /// Cycles each individual reload (region or paging event) charged.
    pub reload: CycleHistogram,
}

impl LaneHists {
    fn observe(&mut self, ev: &TraceEvent) {
        match ev.kind {
            EventKind::DispatchStart => self.queue_delay.record(ev.cycles),
            EventKind::DispatchEnd => self.pass.record(ev.cycles),
            EventKind::RegionReload if !ev.twin => self.reload.record(ev.cycles),
            _ => {}
        }
    }

    /// JSON form of the three lanes.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("queue_delay", self.queue_delay.to_json())
            .with("pass", self.pass.to_json())
            .with("reload", self.reload.to_json())
    }
}

/// A sink aggregating [`LaneHists`] per tenant and per QoS class.
#[derive(Debug, Clone, Default)]
pub struct Histograms {
    tenants: BTreeMap<String, LaneHists>,
    classes: BTreeMap<String, LaneHists>,
}

impl Histograms {
    /// The lanes for one tenant, if it recorded anything.
    pub fn tenant(&self, name: &str) -> Option<&LaneHists> {
        self.tenants.get(name)
    }

    /// The lanes for one QoS class name (`QosClass::as_str`), if any
    /// tenant of that class recorded anything.
    pub fn class(&self, name: &str) -> Option<&LaneHists> {
        self.classes.get(name)
    }

    /// All tenant lanes, name-ordered.
    pub fn tenants(&self) -> impl Iterator<Item = (&String, &LaneHists)> {
        self.tenants.iter()
    }

    /// All class lanes, name-ordered.
    pub fn classes(&self) -> impl Iterator<Item = (&String, &LaneHists)> {
        self.classes.iter()
    }

    /// JSON form: `{tenants: {...}, classes: {...}}`.
    pub fn to_json(&self) -> Json {
        let mut tenants = Json::obj();
        for (name, lanes) in &self.tenants {
            tenants = tenants.with(name.as_str(), lanes.to_json());
        }
        let mut classes = Json::obj();
        for (name, lanes) in &self.classes {
            classes = classes.with(name.as_str(), lanes.to_json());
        }
        Json::obj().with("tenants", tenants).with("classes", classes)
    }
}

impl TraceSink for Histograms {
    fn record(&mut self, ev: &TraceEvent) {
        if !matches!(
            ev.kind,
            EventKind::DispatchStart | EventKind::DispatchEnd | EventKind::RegionReload
        ) {
            return;
        }
        self.tenants.entry(ev.tenant.clone()).or_default().observe(ev);
        if let Some(c) = ev.class {
            self.classes.entry(c.as_str().to_string()).or_default().observe(ev);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::QosClass;

    #[test]
    fn bucket_index_is_floor_log2_plus_one() {
        assert_eq!(CycleHistogram::bucket_index(0), 0);
        assert_eq!(CycleHistogram::bucket_index(1), 1);
        assert_eq!(CycleHistogram::bucket_index(2), 2);
        assert_eq!(CycleHistogram::bucket_index(3), 2);
        assert_eq!(CycleHistogram::bucket_index(4), 3);
        assert_eq!(CycleHistogram::bucket_index(u64::MAX), 64);
        // Every value fits under its bucket's ceiling.
        for v in [0u64, 1, 2, 3, 7, 8, 1023, 1024, u64::MAX] {
            assert!(v <= CycleHistogram::bucket_ceiling(CycleHistogram::bucket_index(v)));
        }
    }

    #[test]
    fn record_merge_and_quantiles() {
        let mut h = CycleHistogram::default();
        for v in [0u64, 1, 5, 5, 9, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum(), 1020);
        // p50 of six samples = 3rd ranked = 5, whose bucket tops at 7.
        assert_eq!(h.quantile_ceiling(0.5), 7);
        assert_eq!(h.quantile_ceiling(1.0), 1023);
        let mut other = CycleHistogram::default();
        other.record(1000);
        h.merge(&other);
        assert_eq!(h.count(), 7);
        assert_eq!(h.sum(), 2020);
        assert_eq!(CycleHistogram::default().quantile_ceiling(0.99), 0);
    }

    #[test]
    fn sink_routes_kinds_to_lanes_per_tenant_and_class() {
        let mut hists = Histograms::default();
        let base = TraceEvent {
            clock: 0,
            kind: EventKind::DispatchStart,
            tenant: "hi".into(),
            macro_id: None,
            cycles: 12,
            twin: false,
            detail: 2,
            class: Some(QosClass::Interactive),
        };
        hists.record(&base);
        hists.record(&TraceEvent { kind: EventKind::DispatchEnd, cycles: 400, ..base.clone() });
        hists.record(&TraceEvent {
            kind: EventKind::RegionReload,
            cycles: 108,
            macro_id: Some(0),
            ..base.clone()
        });
        // Twin mirrors and unrelated kinds stay out of the lanes.
        hists.record(&TraceEvent {
            kind: EventKind::RegionReload,
            twin: true,
            cycles: 108,
            ..base.clone()
        });
        hists.record(&TraceEvent { kind: EventKind::Evict, ..base.clone() });
        let t = hists.tenant("hi").unwrap();
        assert_eq!(t.queue_delay.count(), 1);
        assert_eq!(t.pass.count(), 1);
        assert_eq!(t.reload.count(), 1);
        assert_eq!(t.reload.sum(), 108);
        let c = hists.class(QosClass::Interactive.as_str()).unwrap();
        assert_eq!(c.queue_delay.count() + c.pass.count() + c.reload.count(), 3);
        assert!(hists.tenant("lo").is_none());
    }
}
