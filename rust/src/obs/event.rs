//! The typed trace-event schema: what the fleet records, stamped with
//! the deterministic virtual clock.
//!
//! Every event carries the same five coordinates — virtual clock, tenant,
//! (optional) macro, cycle charge, twin flag — so any sink can re-derive
//! per-tenant and per-macro views without knowing which subsystem emitted
//! it. The `detail` field is kind-specific payload (batch size, region
//! width, deferral count, ...); see [`EventKind`] for the per-kind
//! meaning. Events serialize to/from JSON ([`TraceEvent::to_json`]) so
//! the Chrome exporter's `args` blobs round-trip losslessly.

use crate::fleet::QosClass;
use crate::util::json::Json;

/// What happened. The set is deliberately closed and small: later PRs
/// (sharding, buffer-traffic ledgers) extend `detail` semantics or add
/// kinds here, and every exporter/auditor handles the full set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// A submit passed admission control (`detail` = requests admitted;
    /// `cycles` = the projected dispatch cost the controller saw).
    Admit,
    /// A submit was refused — rate limit or budget (`detail` = requests
    /// refused; `cycles` = the projected cost that was refused).
    Reject,
    /// A queued head batch was passed over by admission control
    /// (`detail` = its deferral count after this pass-over).
    Defer,
    /// Queued requests left the queue for serving (`cycles` = the queue
    /// delay each waited, `detail` = requests dispatched).
    DispatchStart,
    /// A batch finished serving (`cycles` = its compute charge,
    /// `detail` = batch size).
    DispatchEnd,
    /// A weight load charged the reload ledger: one region of a hot-swap
    /// or one whole-macro paging event (`cycles` = the charge, `detail`
    /// = region width in bitlines / paging event index). Emitted twice
    /// under twin execution: once analytic, once with
    /// [`TraceEvent::twin`] set — the mirrored charge
    /// `CimMacro::load_columns` books.
    RegionReload,
    /// A resident tenant lost its columns (`cycles` = 0: eviction itself
    /// is free, the victim pays on return).
    Evict,
    /// One compaction move charged the migration ledger (`cycles` = the
    /// charge, `detail` = span width in bitlines). Twin-mirrored like
    /// [`EventKind::RegionReload`], matching `CimMacro::migrate_columns`.
    MigrateSpan,
    /// The digital twin executed passes on one macro for a batch
    /// (`cycles` = twin compute cycles, `detail` = ADC conversions) —
    /// always [`TraceEvent::twin`].
    TwinPass,
    /// A compaction pass committed (`cycles` = total migration charge,
    /// `detail` = spans moved).
    Compaction,
    /// A tenant crossed the inter-pool link of a sharded fleet
    /// (`cycles` = the transfer charge on the shard-level transfer
    /// ledger, `detail` = footprint width in bitlines). Unlike every
    /// other ledger-bearing kind, `macro_id` names the **destination
    /// pool**, not a macro — the link is pool-to-pool hardware — and
    /// the clock is the shard's own monotone transfer clock (pool
    /// clocks are independent and would interleave non-monotonically).
    /// Never twin-mirrored: the landing write inside the destination
    /// pool books its own twin-mirrored [`EventKind::MigrateSpan`]s.
    MigratePool,
    /// A batch's activation-buffer **reads** charged the buffer-traffic
    /// ledger (`detail` = activation words fetched, `cycles` = 0: buffer
    /// traffic is a movement count, not a device-cycle charge;
    /// `macro_id` = `None` — the activation buffer is per-tenant SRAM,
    /// not a macro). Emitted twice under twin execution, analytic and
    /// twin-mirrored, like [`EventKind::RegionReload`]; the counts agree
    /// by construction (the dataflow engine derives both from the same
    /// loop ordering).
    BufferRead,
    /// A batch's activation-buffer **writes** charged the buffer-traffic
    /// ledger (`detail` = activation words written; same conventions as
    /// [`EventKind::BufferRead`]).
    BufferWrite,
    /// A dedup-placed tenant took a refcounted reference on another
    /// tenant's resident columns instead of loading its own copy
    /// (`detail` = borrowed span width in bitlines, `cycles` = the reload
    /// charge that borrowing **avoided** — booked on no ledger, tracked
    /// as `FleetSnapshot::dedup_shared_cycles`; `macro_id` = the macro
    /// hosting the shared span, `tenant` = the borrower). Never
    /// twin-mirrored: the twin's cells already hold the shared content.
    SharedLoad,
    /// A dedup-placed tenant dropped its references on shared spans —
    /// eviction or retirement (`detail` = released span width in
    /// bitlines, `cycles` = 0: releasing a reference moves no weights;
    /// conventions otherwise as [`EventKind::SharedLoad`]).
    SharedRelease,
}

impl EventKind {
    /// Every kind, in schema order — exporters and counters index by
    /// [`EventKind::index`] into arrays of this length.
    pub const ALL: [EventKind; 15] = [
        EventKind::Admit,
        EventKind::Reject,
        EventKind::Defer,
        EventKind::DispatchStart,
        EventKind::DispatchEnd,
        EventKind::RegionReload,
        EventKind::Evict,
        EventKind::MigrateSpan,
        EventKind::TwinPass,
        EventKind::Compaction,
        EventKind::MigratePool,
        EventKind::BufferRead,
        EventKind::BufferWrite,
        EventKind::SharedLoad,
        EventKind::SharedRelease,
    ];

    /// Position in [`EventKind::ALL`] (a dense counter index).
    pub fn index(&self) -> usize {
        EventKind::ALL.iter().position(|k| k == self).expect("ALL is exhaustive")
    }

    /// Stable wire/export name (snake_case; also the Prometheus label
    /// value and the `trace_scenario.*` bench-counter key).
    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::Admit => "admit",
            EventKind::Reject => "reject",
            EventKind::Defer => "defer",
            EventKind::DispatchStart => "dispatch_start",
            EventKind::DispatchEnd => "dispatch_end",
            EventKind::RegionReload => "region_reload",
            EventKind::Evict => "evict",
            EventKind::MigrateSpan => "migrate_span",
            EventKind::TwinPass => "twin_pass",
            EventKind::Compaction => "compaction",
            EventKind::MigratePool => "migrate_pool",
            EventKind::BufferRead => "buffer_read",
            EventKind::BufferWrite => "buffer_write",
            EventKind::SharedLoad => "shared_load",
            EventKind::SharedRelease => "shared_release",
        }
    }

    /// Parse a wire name (see [`EventKind::as_str`]).
    pub fn parse(s: &str) -> Option<EventKind> {
        EventKind::ALL.iter().copied().find(|k| k.as_str() == s)
    }
}

/// One recorded event. All timing is the deterministic virtual
/// device-cycle clock (`QosScheduler::now`) — never wall clock — so two
/// identical runs produce byte-identical traces.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Virtual device-cycle clock at emission. Every event of one batch
    /// shares the batch-start clock (the clock advances only when a
    /// batch's charges commit), so the stream is non-decreasing.
    pub clock: u64,
    /// What happened.
    pub kind: EventKind,
    /// Tenant (model name) the event is attributed to; `"fleet"` for
    /// pool-wide events like [`EventKind::Compaction`].
    pub tenant: String,
    /// Physical macro the event landed on (`None` for queue/admission
    /// events that touch no macro).
    pub macro_id: Option<usize>,
    /// Device cycles this event charged (0 for free events; see the
    /// per-kind meaning on [`EventKind`]).
    pub cycles: u64,
    /// Whether this is the digital twin's mirrored side of a charge
    /// (twin events re-derive the twin ledger; analytic events the
    /// fleet/macro/tenant ledgers — never both).
    pub twin: bool,
    /// Kind-specific payload (see [`EventKind`]).
    pub detail: u64,
    /// The tenant's QoS class at emission, when known.
    pub class: Option<QosClass>,
}

impl TraceEvent {
    /// Machine-readable form — the Chrome exporter's `args` payload.
    /// [`TraceEvent::from_json`] inverts it exactly.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .with("clock", self.clock)
            .with("kind", self.kind.as_str())
            .with("tenant", self.tenant.as_str())
            .with("cycles", self.cycles)
            .with("twin", self.twin)
            .with("detail", self.detail);
        if let Some(m) = self.macro_id {
            j = j.with("macro", m);
        }
        if let Some(c) = self.class {
            j = j.with("class", c.as_str());
        }
        j
    }

    /// Parse the JSON form ([`TraceEvent::to_json`]); `None` when a
    /// required field is missing or malformed.
    pub fn from_json(j: &Json) -> Option<TraceEvent> {
        Some(TraceEvent {
            clock: j.get("clock").as_usize()? as u64,
            kind: EventKind::parse(j.get("kind").as_str()?)?,
            tenant: j.get("tenant").as_str()?.to_string(),
            macro_id: j.get("macro").as_usize(),
            cycles: j.get("cycles").as_usize()? as u64,
            twin: j.get("twin").as_bool()?,
            detail: j.get("detail").as_usize()? as u64,
            class: j.get("class").as_str().and_then(QosClass::parse),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip_and_index_is_dense() {
        for (i, k) in EventKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i);
            assert_eq!(EventKind::parse(k.as_str()), Some(*k));
        }
        assert_eq!(EventKind::parse("mystery"), None);
    }

    #[test]
    fn event_json_roundtrips_with_and_without_optionals() {
        let full = TraceEvent {
            clock: 1234,
            kind: EventKind::RegionReload,
            tenant: "hi".into(),
            macro_id: Some(3),
            cycles: 108,
            twin: true,
            detail: 108,
            class: Some(QosClass::Interactive),
        };
        assert_eq!(TraceEvent::from_json(&full.to_json()), Some(full.clone()));
        let bare = TraceEvent {
            macro_id: None,
            class: None,
            kind: EventKind::Admit,
            twin: false,
            ..full
        };
        assert_eq!(TraceEvent::from_json(&bare.to_json()), Some(bare));
        assert_eq!(TraceEvent::from_json(&Json::obj()), None);
    }
}
