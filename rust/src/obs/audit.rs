//! The online four-ledger audit.
//!
//! `Fleet::snapshot` keeps a `debug_assert` that the fleet, per-macro,
//! per-tenant, and twin cycle ledgers agree; the [`LedgerAuditor`]
//! promotes that invariant to an always-on check that works from the
//! *event stream alone*: it watches `RegionReload` / `MigrateSpan`
//! events (analytic and twin-mirrored sides separately), re-derives all
//! four ledgers independently of the fleet's own accounting, and
//! [`LedgerAuditor::verify`] diffs them against the final
//! `FleetSnapshot` with a precise first-divergence report. Because it
//! is a plain [`TraceSink`] it runs online (inside a
//! [`FleetTrace`](super::FleetTrace)) or offline
//! ([`LedgerAuditor::replay`] over a recorded [`TraceLog`](super::TraceLog)) —
//! the proptests check both derivations are bit-identical.

use std::collections::BTreeMap;

use crate::fleet::FleetSnapshot;
use crate::util::json::Json;

use super::event::{EventKind, TraceEvent};
use super::sink::TraceSink;

/// Re-derives the four cycle ledgers from trace events.
#[derive(Debug, Clone, Default)]
pub struct LedgerAuditor {
    fleet_load: u64,
    fleet_migration: u64,
    macro_load: BTreeMap<usize, u64>,
    macro_migration: BTreeMap<usize, u64>,
    tenant_load: BTreeMap<String, u64>,
    tenant_migration: BTreeMap<String, u64>,
    twin_load: u64,
    twin_migration: u64,
    events: u64,
    last_clock: u64,
    clock_regressions: u64,
}

impl TraceSink for LedgerAuditor {
    fn record(&mut self, ev: &TraceEvent) {
        self.events += 1;
        if ev.clock < self.last_clock {
            self.clock_regressions += 1;
        } else {
            self.last_clock = ev.clock;
        }
        let (fleet, per_macro, per_tenant, twin) = match ev.kind {
            EventKind::RegionReload => (
                &mut self.fleet_load,
                &mut self.macro_load,
                &mut self.tenant_load,
                &mut self.twin_load,
            ),
            EventKind::MigrateSpan => (
                &mut self.fleet_migration,
                &mut self.macro_migration,
                &mut self.tenant_migration,
                &mut self.twin_migration,
            ),
            _ => return,
        };
        if ev.twin {
            *twin += ev.cycles;
        } else {
            *fleet += ev.cycles;
            if let Some(m) = ev.macro_id {
                *per_macro.entry(m).or_default() += ev.cycles;
            }
            *per_tenant.entry(ev.tenant.clone()).or_default() += ev.cycles;
        }
    }
}

impl LedgerAuditor {
    /// Build an auditor by replaying recorded events (oldest first) —
    /// the offline twin of feeding it live as a sink.
    pub fn replay<'a>(events: impl IntoIterator<Item = &'a TraceEvent>) -> LedgerAuditor {
        let mut a = LedgerAuditor::default();
        for ev in events {
            a.record(ev);
        }
        a
    }

    /// Events seen (all kinds, not just ledger-bearing ones).
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Derived fleet-level reload cycles.
    pub fn fleet_load_cycles(&self) -> u64 {
        self.fleet_load
    }

    /// Derived fleet-level migration cycles.
    pub fn fleet_migration_cycles(&self) -> u64 {
        self.fleet_migration
    }

    /// Times the virtual clock went backwards between consecutive
    /// events (must stay 0 — the clock only ever advances).
    pub fn clock_regressions(&self) -> u64 {
        self.clock_regressions
    }

    /// Diff every derived ledger against the fleet's own books.
    ///
    /// Checks run in a fixed order (fleet load, fleet migration,
    /// per-macro, per-tenant, twin, clock monotonicity) and the first
    /// failing one becomes [`AuditReport::first_divergence`], so a
    /// broken charge site is named precisely rather than drowning in
    /// follow-on mismatches.
    pub fn verify(&self, snap: &FleetSnapshot) -> AuditReport {
        struct Acc {
            checks: usize,
            first: Option<String>,
        }
        impl Acc {
            fn check(&mut self, label: &str, derived: u64, ledger: u64) {
                self.checks += 1;
                if derived != ledger && self.first.is_none() {
                    self.first = Some(format!("{label}: derived {derived} != ledger {ledger}"));
                }
            }
        }
        let mut acc = Acc { checks: 0, first: None };

        acc.check("fleet load", self.fleet_load, snap.reload_cycles);
        acc.check("fleet migration", self.fleet_migration, snap.migration_cycles);
        for (m, stats) in snap.macro_stats.iter().enumerate() {
            acc.check(
                &format!("macro {m} load"),
                self.macro_load.get(&m).copied().unwrap_or(0),
                stats.load_cycles,
            );
            acc.check(
                &format!("macro {m} migration"),
                self.macro_migration.get(&m).copied().unwrap_or(0),
                stats.migration_cycles,
            );
        }
        for (name, stats) in &snap.tenant_stats {
            acc.check(
                &format!("tenant {name} load"),
                self.tenant_load.get(name).copied().unwrap_or(0),
                stats.load_cycles,
            );
            acc.check(
                &format!("tenant {name} migration"),
                self.tenant_migration.get(name).copied().unwrap_or(0),
                stats.migration_cycles,
            );
        }
        // A derived tenant the snapshot has never heard of means events
        // carried a bogus attribution (tenant books survive retirement,
        // so the snapshot's tenant list is a superset of any valid
        // trace's).
        for name in self.tenant_load.keys().chain(self.tenant_migration.keys()) {
            acc.checks += 1;
            if acc.first.is_none() && !snap.tenant_stats.iter().any(|(n, _)| n == name) {
                acc.first = Some(format!("tenant {name}: charged in trace, unknown to snapshot"));
            }
        }
        let (twin_load, twin_migration) = (
            snap.twin_stats.iter().map(|s| s.load_cycles).sum::<u64>(),
            snap.twin_stats.iter().map(|s| s.migration_cycles).sum::<u64>(),
        );
        if snap.twin_stats.is_empty() {
            // Analytic execution: the trace must not have invented a
            // twin side.
            acc.check("twin load (no twin)", self.twin_load, 0);
            acc.check("twin migration (no twin)", self.twin_migration, 0);
        } else {
            acc.check("twin load", self.twin_load, twin_load);
            acc.check("twin migration", self.twin_migration, twin_migration);
        }
        acc.check("clock regressions", self.clock_regressions, 0);

        AuditReport {
            pass: acc.first.is_none(),
            checks: acc.checks,
            events: self.events,
            first_divergence: acc.first,
        }
    }
}

/// Outcome of [`LedgerAuditor::verify`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AuditReport {
    /// All checks agreed.
    pub pass: bool,
    /// How many ledger comparisons ran.
    pub checks: usize,
    /// How many events fed the derivation.
    pub events: u64,
    /// The first disagreement, as `"<ledger>: derived X != ledger Y"`;
    /// `None` when `pass`.
    pub first_divergence: Option<String>,
}

impl AuditReport {
    /// JSON form (for `--metrics-out` consumers and bench summaries).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .with("pass", self.pass)
            .with("checks", self.checks)
            .with("events", self.events);
        if let Some(d) = &self.first_divergence {
            j = j.with("first_divergence", d.as_str());
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reload(clock: u64, tenant: &str, m: usize, cycles: u64, twin: bool) -> TraceEvent {
        TraceEvent {
            clock,
            kind: EventKind::RegionReload,
            tenant: tenant.into(),
            macro_id: Some(m),
            cycles,
            twin,
            detail: 0,
            class: None,
        }
    }

    #[test]
    fn auditor_accumulates_analytic_and_twin_sides_separately() {
        let evs = vec![
            reload(0, "a", 0, 100, false),
            reload(0, "a", 0, 100, true),
            reload(5, "b", 1, 40, false),
            TraceEvent { kind: EventKind::MigrateSpan, ..reload(9, "a", 1, 30, false) },
        ];
        let a = LedgerAuditor::replay(&evs);
        assert_eq!(a.events(), 4);
        assert_eq!(a.fleet_load_cycles(), 140);
        assert_eq!(a.fleet_migration_cycles(), 30);
        assert_eq!(a.clock_regressions(), 0);
    }

    #[test]
    fn clock_regression_is_counted() {
        let a = LedgerAuditor::replay(&[reload(10, "a", 0, 1, false), reload(3, "a", 0, 1, false)]);
        assert_eq!(a.clock_regressions(), 1);
    }
}
