//! The online four-ledger audit — five under sharded serving.
//!
//! `Fleet::snapshot` keeps a `debug_assert` that the fleet, per-macro,
//! per-tenant, and twin cycle ledgers agree; the [`LedgerAuditor`]
//! promotes that invariant to an always-on check that works from the
//! *event stream alone*: it watches `RegionReload` / `MigrateSpan`
//! events (analytic and twin-mirrored sides separately), re-derives all
//! four ledgers independently of the fleet's own accounting, and
//! [`LedgerAuditor::verify`] diffs them against the final
//! `FleetSnapshot` with a precise first-divergence report. Because it
//! is a plain [`TraceSink`] it runs online (inside a
//! [`FleetTrace`](super::FleetTrace)) or offline
//! ([`LedgerAuditor::replay`] over a recorded [`TraceLog`](super::TraceLog)) —
//! the proptests check both derivations are bit-identical.
//!
//! The **buffer-traffic ledger** (activation reads/writes from
//! [`EventKind::BufferRead`] / [`EventKind::BufferWrite`] `detail`
//! payloads, conserved fleet == per-tenant == twin — there is no
//! per-macro side because the activation buffer is per-tenant SRAM, not
//! a macro) is re-derived and verified the same way.
//!
//! Under content-addressed dedup (`FleetConfig::dedup`) the stream also
//! carries [`EventKind::SharedLoad`] / [`EventKind::SharedRelease`]
//! events: a borrower acquiring refcounted spans instead of reloading
//! them, and the release of those references on eviction or retirement.
//! These never touch the four cycle ledgers (the first loader already
//! paid in full; a borrow charges nothing anywhere), but the auditor
//! re-derives the *avoided* side from them — currently borrowed
//! bitlines (loads minus releases, from `detail`) and cumulative
//! avoided reload cycles — and diffs both against
//! `FleetSnapshot::dedup_shared_bls` / `dedup_shared_cycles`. On a
//! non-dedup stream all four quantities are zero and the checks pass
//! vacuously.
//!
//! A sharded fleet ([`ShardedFleet`](crate::fleet::ShardedFleet)) adds
//! the **fifth** ledger: inter-pool transfer cycles, recorded as
//! [`EventKind::MigratePool`] events on the shard's own monotone
//! transfer clock. The same auditor re-derives it (fleet total ==
//! Σ per-destination-pool == Σ per-tenant) and
//! [`LedgerAuditor::verify_transfers`] diffs it against a
//! [`ShardSnapshot`](crate::fleet::ShardSnapshot); the per-pool streams
//! keep their own four-ledger auditors, so the five-ledger statement
//! decomposes into N pool audits plus one transfer audit.

use std::collections::BTreeMap;

use crate::fleet::{FleetSnapshot, ShardSnapshot};
use crate::latency::BufferTraffic;
use crate::util::json::Json;

use super::event::{EventKind, TraceEvent};
use super::sink::TraceSink;

/// Re-derives the four cycle ledgers from trace events — plus the
/// sharded fleet's fifth (inter-pool transfer) ledger when the stream
/// carries [`EventKind::MigratePool`] events.
#[derive(Debug, Clone, Default)]
pub struct LedgerAuditor {
    fleet_load: u64,
    fleet_migration: u64,
    macro_load: BTreeMap<usize, u64>,
    macro_migration: BTreeMap<usize, u64>,
    tenant_load: BTreeMap<String, u64>,
    tenant_migration: BTreeMap<String, u64>,
    twin_load: u64,
    twin_migration: u64,
    /// Buffer-traffic ledger (activation words, from event `detail`):
    /// fleet total, per tenant, and the twin-mirrored side. No per-macro
    /// view — the activation buffer is per-tenant SRAM.
    fleet_buffer: BufferTraffic,
    tenant_buffer: BTreeMap<String, BufferTraffic>,
    twin_buffer: BufferTraffic,
    /// Shard-level transfer ledger: fleet total, per destination pool
    /// (`MigratePool` events carry the pool in `macro_id`), per tenant.
    fleet_transfer: u64,
    pool_transfer: BTreeMap<usize, u64>,
    tenant_transfer: BTreeMap<String, u64>,
    transfers: u64,
    /// Dedup shared-span ledger: bitlines borrowed / released (from
    /// `SharedLoad` / `SharedRelease` `detail`) and the reload cycles
    /// borrowing avoided. None of these appear on the cycle ledgers.
    shared_loaded_bls: u64,
    shared_released_bls: u64,
    shared_cycles: u64,
    events: u64,
    last_clock: u64,
    clock_regressions: u64,
}

impl TraceSink for LedgerAuditor {
    fn record(&mut self, ev: &TraceEvent) {
        self.events += 1;
        if ev.clock < self.last_clock {
            self.clock_regressions += 1;
        } else {
            self.last_clock = ev.clock;
        }
        if ev.kind == EventKind::MigratePool {
            // The transfer ledger has no twin side (the landing write
            // inside the destination pool books its own mirrored
            // MigrateSpans), so every MigratePool event is analytic.
            self.fleet_transfer += ev.cycles;
            if let Some(p) = ev.macro_id {
                *self.pool_transfer.entry(p).or_default() += ev.cycles;
            }
            *self.tenant_transfer.entry(ev.tenant.clone()).or_default() += ev.cycles;
            self.transfers += 1;
            return;
        }
        if ev.kind == EventKind::SharedLoad {
            // A borrow: `detail` is the span width acquired by
            // reference, `cycles` the reload charge it avoided. Never
            // twin-mirrored and never on a cycle ledger.
            self.shared_loaded_bls += ev.detail;
            self.shared_cycles += ev.cycles;
            return;
        }
        if ev.kind == EventKind::SharedRelease {
            self.shared_released_bls += ev.detail;
            return;
        }
        if matches!(ev.kind, EventKind::BufferRead | EventKind::BufferWrite) {
            // Buffer traffic is counted in activation words carried by
            // `detail` (cycles stay 0), and has no per-macro view.
            let words = ev.detail;
            let charge = if ev.kind == EventKind::BufferRead {
                BufferTraffic { reads: words, writes: 0 }
            } else {
                BufferTraffic { reads: 0, writes: words }
            };
            if ev.twin {
                self.twin_buffer.absorb(charge);
            } else {
                self.fleet_buffer.absorb(charge);
                self.tenant_buffer.entry(ev.tenant.clone()).or_default().absorb(charge);
            }
            return;
        }
        let (fleet, per_macro, per_tenant, twin) = match ev.kind {
            EventKind::RegionReload => (
                &mut self.fleet_load,
                &mut self.macro_load,
                &mut self.tenant_load,
                &mut self.twin_load,
            ),
            EventKind::MigrateSpan => (
                &mut self.fleet_migration,
                &mut self.macro_migration,
                &mut self.tenant_migration,
                &mut self.twin_migration,
            ),
            _ => return,
        };
        if ev.twin {
            *twin += ev.cycles;
        } else {
            *fleet += ev.cycles;
            if let Some(m) = ev.macro_id {
                *per_macro.entry(m).or_default() += ev.cycles;
            }
            *per_tenant.entry(ev.tenant.clone()).or_default() += ev.cycles;
        }
    }
}

impl LedgerAuditor {
    /// Build an auditor by replaying recorded events (oldest first) —
    /// the offline twin of feeding it live as a sink.
    pub fn replay<'a>(events: impl IntoIterator<Item = &'a TraceEvent>) -> LedgerAuditor {
        let mut a = LedgerAuditor::default();
        for ev in events {
            a.record(ev);
        }
        a
    }

    /// Events seen (all kinds, not just ledger-bearing ones).
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Derived fleet-level reload cycles.
    pub fn fleet_load_cycles(&self) -> u64 {
        self.fleet_load
    }

    /// Derived fleet-level migration cycles.
    pub fn fleet_migration_cycles(&self) -> u64 {
        self.fleet_migration
    }

    /// Times the virtual clock went backwards between consecutive
    /// events (must stay 0 — the clock only ever advances).
    pub fn clock_regressions(&self) -> u64 {
        self.clock_regressions
    }

    /// Derived shard-level inter-pool transfer cycles (the fifth
    /// ledger; 0 on single-pool streams).
    pub fn fleet_transfer_cycles(&self) -> u64 {
        self.fleet_transfer
    }

    /// Derived fleet-level activation-buffer traffic (analytic side).
    pub fn fleet_buffer(&self) -> BufferTraffic {
        self.fleet_buffer
    }

    /// Derived twin-mirrored activation-buffer traffic.
    pub fn twin_buffer(&self) -> BufferTraffic {
        self.twin_buffer
    }

    /// Derived activation-buffer traffic attributed to one tenant
    /// (zero when the trace never charged it).
    pub fn tenant_buffer(&self, tenant: &str) -> BufferTraffic {
        self.tenant_buffer.get(tenant).copied().unwrap_or_default()
    }

    /// Derived cross-pool migrations (`MigratePool` events seen).
    pub fn transfers(&self) -> u64 {
        self.transfers
    }

    /// Derived bitlines currently held by refcounted reference:
    /// `SharedLoad` minus `SharedRelease` widths (0 on non-dedup
    /// streams).
    pub fn shared_borrowed_bls(&self) -> u64 {
        self.shared_loaded_bls.saturating_sub(self.shared_released_bls)
    }

    /// Derived cumulative reload cycles that borrowing avoided — the
    /// dedup win, never present on any cycle ledger.
    pub fn shared_avoided_cycles(&self) -> u64 {
        self.shared_cycles
    }

    /// Diff every derived ledger against the fleet's own books.
    ///
    /// Checks run in a fixed order (fleet load, fleet migration,
    /// per-macro, per-tenant, twin, buffer, shared spans, clock
    /// monotonicity) and the first
    /// failing one becomes [`AuditReport::first_divergence`], so a
    /// broken charge site is named precisely rather than drowning in
    /// follow-on mismatches.
    pub fn verify(&self, snap: &FleetSnapshot) -> AuditReport {
        struct Acc {
            checks: usize,
            first: Option<String>,
        }
        impl Acc {
            fn check(&mut self, label: &str, derived: u64, ledger: u64) {
                self.checks += 1;
                if derived != ledger && self.first.is_none() {
                    self.first = Some(format!("{label}: derived {derived} != ledger {ledger}"));
                }
            }
        }
        let mut acc = Acc { checks: 0, first: None };

        acc.check("fleet load", self.fleet_load, snap.reload_cycles);
        acc.check("fleet migration", self.fleet_migration, snap.migration_cycles);
        for (m, stats) in snap.macro_stats.iter().enumerate() {
            acc.check(
                &format!("macro {m} load"),
                self.macro_load.get(&m).copied().unwrap_or(0),
                stats.load_cycles,
            );
            acc.check(
                &format!("macro {m} migration"),
                self.macro_migration.get(&m).copied().unwrap_or(0),
                stats.migration_cycles,
            );
        }
        for (name, stats) in &snap.tenant_stats {
            acc.check(
                &format!("tenant {name} load"),
                self.tenant_load.get(name).copied().unwrap_or(0),
                stats.load_cycles,
            );
            acc.check(
                &format!("tenant {name} migration"),
                self.tenant_migration.get(name).copied().unwrap_or(0),
                stats.migration_cycles,
            );
        }
        // A derived tenant the snapshot has never heard of means events
        // carried a bogus attribution (tenant books survive retirement,
        // so the snapshot's tenant list is a superset of any valid
        // trace's).
        for name in self.tenant_load.keys().chain(self.tenant_migration.keys()) {
            acc.checks += 1;
            if acc.first.is_none() && !snap.tenant_stats.iter().any(|(n, _)| n == name) {
                acc.first = Some(format!("tenant {name}: charged in trace, unknown to snapshot"));
            }
        }
        let (twin_load, twin_migration) = (
            snap.twin_stats.iter().map(|s| s.load_cycles).sum::<u64>(),
            snap.twin_stats.iter().map(|s| s.migration_cycles).sum::<u64>(),
        );
        if snap.twin_stats.is_empty() {
            // Analytic execution: the trace must not have invented a
            // twin side.
            acc.check("twin load (no twin)", self.twin_load, 0);
            acc.check("twin migration (no twin)", self.twin_migration, 0);
        } else {
            acc.check("twin load", self.twin_load, twin_load);
            acc.check("twin migration", self.twin_migration, twin_migration);
        }
        // Buffer-traffic ledger: fleet total, per-tenant attribution,
        // twin mirror — all re-derived from BufferRead/BufferWrite
        // `detail` payloads alone.
        acc.check("fleet buffer reads", self.fleet_buffer.reads, snap.buffer_fleet.reads);
        acc.check("fleet buffer writes", self.fleet_buffer.writes, snap.buffer_fleet.writes);
        for (name, traffic) in &snap.buffer_tenant {
            let derived = self.tenant_buffer.get(name).copied().unwrap_or_default();
            acc.check(&format!("tenant {name} buffer reads"), derived.reads, traffic.reads);
            acc.check(&format!("tenant {name} buffer writes"), derived.writes, traffic.writes);
        }
        for name in self.tenant_buffer.keys() {
            acc.checks += 1;
            if acc.first.is_none() && !snap.buffer_tenant.iter().any(|(n, _)| n == name) {
                acc.first =
                    Some(format!("tenant {name}: buffer-charged in trace, unknown to snapshot"));
            }
        }
        if snap.twin_stats.is_empty() {
            acc.check("twin buffer reads (no twin)", self.twin_buffer.reads, 0);
            acc.check("twin buffer writes (no twin)", self.twin_buffer.writes, 0);
        } else {
            acc.check("twin buffer reads", self.twin_buffer.reads, snap.buffer_twin.reads);
            acc.check("twin buffer writes", self.twin_buffer.writes, snap.buffer_twin.writes);
        }
        // Dedup shared-span ledger: live borrows and avoided cycles,
        // re-derived from SharedLoad/SharedRelease alone, must match the
        // fleet's own books. Vacuous (all zeros) when dedup is off.
        acc.check(
            "shared borrowed bitlines",
            self.shared_borrowed_bls(),
            snap.dedup_shared_bls as u64,
        );
        acc.check("shared avoided cycles", self.shared_cycles, snap.dedup_shared_cycles);
        // A single pool has no inter-pool link: transfer charges in its
        // stream mean events leaked across shard boundaries.
        acc.check("transfer (single pool)", self.fleet_transfer, 0);
        acc.check("clock regressions", self.clock_regressions, 0);

        AuditReport {
            pass: acc.first.is_none(),
            checks: acc.checks,
            events: self.events,
            first_divergence: acc.first,
        }
    }

    /// Diff the derived **transfer** ledger against a sharded fleet's
    /// books — the fifth-ledger counterpart of [`LedgerAuditor::verify`].
    ///
    /// Feed this auditor the shard-level stream (the sink passed to
    /// `ShardedFleet::set_trace`, which carries only
    /// [`EventKind::MigratePool`] events on the shard's monotone
    /// transfer clock); each pool's own stream keeps its own
    /// four-ledger auditor. Checks, in order: fleet transfer total,
    /// per-destination-pool attribution, per-tenant attribution,
    /// unknown-tenant / unknown-pool charges, transfer count, and clock
    /// monotonicity — the first failure becomes
    /// [`AuditReport::first_divergence`].
    pub fn verify_transfers(&self, snap: &ShardSnapshot) -> AuditReport {
        struct Acc {
            checks: usize,
            first: Option<String>,
        }
        impl Acc {
            fn check(&mut self, label: &str, derived: u64, ledger: u64) {
                self.checks += 1;
                if derived != ledger && self.first.is_none() {
                    self.first = Some(format!("{label}: derived {derived} != ledger {ledger}"));
                }
            }
        }
        let mut acc = Acc { checks: 0, first: None };

        acc.check("shard transfer", self.fleet_transfer, snap.transfer_cycles);
        for (p, &cycles) in snap.pool_transfer_cycles.iter().enumerate() {
            acc.check(
                &format!("pool {p} transfer"),
                self.pool_transfer.get(&p).copied().unwrap_or(0),
                cycles,
            );
        }
        for p in self.pool_transfer.keys() {
            acc.checks += 1;
            if acc.first.is_none() && *p >= snap.pool_transfer_cycles.len() {
                acc.first = Some(format!("pool {p}: charged in trace, unknown to snapshot"));
            }
        }
        for (name, cycles) in &snap.tenant_transfer_cycles {
            acc.check(
                &format!("tenant {name} transfer"),
                self.tenant_transfer.get(name).copied().unwrap_or(0),
                *cycles,
            );
        }
        for name in self.tenant_transfer.keys() {
            acc.checks += 1;
            if acc.first.is_none()
                && !snap.tenant_transfer_cycles.iter().any(|(n, _)| n == name)
            {
                acc.first = Some(format!("tenant {name}: charged in trace, unknown to snapshot"));
            }
        }
        acc.check("transfer count", self.transfers, snap.transfers);
        acc.check("clock regressions", self.clock_regressions, 0);

        AuditReport {
            pass: acc.first.is_none(),
            checks: acc.checks,
            events: self.events,
            first_divergence: acc.first,
        }
    }
}

/// Outcome of [`LedgerAuditor::verify`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AuditReport {
    /// All checks agreed.
    pub pass: bool,
    /// How many ledger comparisons ran.
    pub checks: usize,
    /// How many events fed the derivation.
    pub events: u64,
    /// The first disagreement, as `"<ledger>: derived X != ledger Y"`;
    /// `None` when `pass`.
    pub first_divergence: Option<String>,
}

impl AuditReport {
    /// JSON form (for `--metrics-out` consumers and bench summaries).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .with("pass", self.pass)
            .with("checks", self.checks)
            .with("events", self.events);
        if let Some(d) = &self.first_divergence {
            j = j.with("first_divergence", d.as_str());
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reload(clock: u64, tenant: &str, m: usize, cycles: u64, twin: bool) -> TraceEvent {
        TraceEvent {
            clock,
            kind: EventKind::RegionReload,
            tenant: tenant.into(),
            macro_id: Some(m),
            cycles,
            twin,
            detail: 0,
            class: None,
        }
    }

    #[test]
    fn auditor_accumulates_analytic_and_twin_sides_separately() {
        let evs = vec![
            reload(0, "a", 0, 100, false),
            reload(0, "a", 0, 100, true),
            reload(5, "b", 1, 40, false),
            TraceEvent { kind: EventKind::MigrateSpan, ..reload(9, "a", 1, 30, false) },
        ];
        let a = LedgerAuditor::replay(&evs);
        assert_eq!(a.events(), 4);
        assert_eq!(a.fleet_load_cycles(), 140);
        assert_eq!(a.fleet_migration_cycles(), 30);
        assert_eq!(a.clock_regressions(), 0);
    }

    #[test]
    fn clock_regression_is_counted() {
        let a = LedgerAuditor::replay(&[reload(10, "a", 0, 1, false), reload(3, "a", 0, 1, false)]);
        assert_eq!(a.clock_regressions(), 1);
    }

    #[test]
    fn buffer_ledger_accumulates_from_detail_and_splits_twin_side() {
        let buf = |clock, tenant: &str, kind, words, twin| TraceEvent {
            clock,
            kind,
            tenant: tenant.into(),
            macro_id: None,
            cycles: 0,
            twin,
            detail: words,
            class: None,
        };
        let a = LedgerAuditor::replay(&[
            buf(0, "a", EventKind::BufferRead, 300, false),
            buf(0, "a", EventKind::BufferWrite, 120, false),
            buf(0, "a", EventKind::BufferRead, 300, true),
            buf(0, "a", EventKind::BufferWrite, 120, true),
            buf(4, "b", EventKind::BufferRead, 50, false),
        ]);
        assert_eq!(a.fleet_buffer(), BufferTraffic { reads: 350, writes: 120 });
        assert_eq!(a.twin_buffer(), BufferTraffic { reads: 300, writes: 120 });
        assert_eq!(a.tenant_buffer("a"), BufferTraffic { reads: 300, writes: 120 });
        assert_eq!(a.tenant_buffer("b"), BufferTraffic { reads: 50, writes: 0 });
        assert_eq!(a.tenant_buffer("ghost"), BufferTraffic::default());
        // Against an empty snapshot the fleet-buffer check diverges
        // first (derived 350 != ledger 0).
        let report = a.verify(&FleetSnapshot::default());
        assert!(!report.pass);
        assert!(report.first_divergence.as_deref().unwrap().starts_with("fleet load")
            || report.first_divergence.as_deref().unwrap().starts_with("fleet buffer reads"));
    }

    #[test]
    fn transfer_ledger_accumulates_and_single_pool_verify_rejects_it() {
        let transfer = TraceEvent {
            kind: EventKind::MigratePool,
            ..reload(4, "a", 2, 650, false)
        };
        let a = LedgerAuditor::replay(&[transfer]);
        assert_eq!(a.fleet_transfer_cycles(), 650);
        assert_eq!(a.transfers(), 1);
        // A single pool's stream must never carry transfer charges.
        let report = a.verify(&FleetSnapshot::default());
        assert!(!report.pass);
        assert!(report
            .first_divergence
            .as_deref()
            .unwrap()
            .starts_with("transfer (single pool)"));
    }

    #[test]
    fn shared_span_ledger_rederives_borrows_and_avoided_cycles() {
        let shared = |clock, kind, width, cycles| TraceEvent {
            clock,
            kind,
            tenant: "head".into(),
            macro_id: Some(0),
            cycles,
            twin: false,
            detail: width,
            class: None,
        };
        let a = LedgerAuditor::replay(&[
            shared(0, EventKind::SharedLoad, 90, 90),
            shared(1, EventKind::SharedLoad, 8, 8),
            shared(7, EventKind::SharedRelease, 8, 0),
        ]);
        assert_eq!(a.shared_borrowed_bls(), 90);
        assert_eq!(a.shared_avoided_cycles(), 98);
        // SharedLoad/SharedRelease never touch the cycle ledgers.
        assert_eq!(a.fleet_load_cycles(), 0);
        // A snapshot agreeing on both shared quantities passes; one that
        // lost a release diverges on the borrowed-bitline check first.
        let snap = FleetSnapshot {
            dedup_enabled: true,
            dedup_shared_bls: 90,
            dedup_shared_cycles: 98,
            ..FleetSnapshot::default()
        };
        assert!(a.verify(&snap).pass);
        let mut broken = snap.clone();
        broken.dedup_shared_bls = 98;
        let report = a.verify(&broken);
        assert!(!report.pass);
        assert_eq!(
            report.first_divergence.as_deref(),
            Some("shared borrowed bitlines: derived 90 != ledger 98")
        );
    }

    #[test]
    fn verify_transfers_balances_all_three_views() {
        let mk = |clock, tenant: &str, pool, cycles| TraceEvent {
            kind: EventKind::MigratePool,
            ..reload(clock, tenant, pool, cycles, false)
        };
        let a = LedgerAuditor::replay(&[mk(0, "a", 1, 100), mk(5, "b", 0, 40), mk(9, "a", 0, 60)]);
        let snap = ShardSnapshot {
            transfer_cycles: 200,
            pool_transfer_cycles: vec![100, 100],
            tenant_transfer_cycles: vec![("a".into(), 160), ("b".into(), 40)],
            transfers: 3,
            ..ShardSnapshot::default()
        };
        assert!(a.verify_transfers(&snap).pass);
        let mut broken = snap.clone();
        broken.pool_transfer_cycles[1] = 99;
        let report = a.verify_transfers(&broken);
        assert!(!report.pass);
        assert_eq!(
            report.first_divergence.as_deref(),
            Some("pool 1 transfer: derived 100 != ledger 99")
        );
    }
}
