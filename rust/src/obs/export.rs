//! Exporters: Chrome trace-event JSON, Prometheus text exposition, and
//! an ASCII per-macro timeline.
//!
//! All three are pure functions of sink state and are byte-deterministic:
//! timestamps are virtual device cycles (never wall clock), maps are
//! `BTreeMap`-ordered, and the JSON dumper is canonical — so two
//! identical runs export identical bytes, making traces CI-comparable
//! artifacts like `BENCH_fleet.json`.

use anyhow::{anyhow, Result};

use crate::util::json::Json;

use super::event::{EventKind, TraceEvent};
use super::hist::{CycleHistogram, Histograms, LaneHists};
use super::sink::{FleetTrace, TraceLog};

/// Export a recorded log as Chrome trace-event JSON (the
/// `chrome://tracing` / Perfetto "JSON Array Format", object form).
///
/// Layout: pid 1 is the macro pool with one thread (track) per physical
/// macro; pid 2 is the tenant view with one track per tenant. Both sets
/// of tracks are declared up front from `num_macros` and `tenants` via
/// metadata events, so every macro and tenant gets a complete track
/// even when it recorded nothing. Ledger-bearing events
/// (`RegionReload`/`MigrateSpan`/`TwinPass`) render as duration slices
/// (`ph:"X"`, `dur` = cycle charge) on their macro's track;
/// `DispatchEnd` as a slice on the tenant track; everything else as an
/// instant. Each event's full schema rides in `args`, so
/// [`events_from_chrome`] recovers the exact [`TraceEvent`] stream.
pub fn chrome_trace(log: &TraceLog, num_macros: usize, tenants: &[String]) -> Json {
    // Tenant → track id: the declared list first, then any tenant the
    // log mentions that the caller missed (e.g. the synthetic "fleet"
    // tenant on Compaction events), in sorted order for determinism.
    let mut names: Vec<String> = tenants.to_vec();
    let mut extras: Vec<String> = log
        .events()
        .map(|e| e.tenant.clone())
        .filter(|t| !names.contains(t))
        .collect();
    extras.sort();
    extras.dedup();
    names.extend(extras);

    let mut out: Vec<Json> = Vec::new();
    let meta = |name: &str, pid: usize, tid: usize, label: &str| {
        Json::obj()
            .with("name", name)
            .with("ph", "M")
            .with("pid", pid)
            .with("tid", tid)
            .with("args", Json::obj().with("name", label))
    };
    out.push(meta("process_name", 1, 0, "cim macros"));
    out.push(meta("process_name", 2, 0, "cim tenants"));
    for m in 0..num_macros {
        out.push(meta("thread_name", 1, m, &format!("macro {m}")));
    }
    for (i, t) in names.iter().enumerate() {
        out.push(meta("thread_name", 2, i, &format!("tenant {t}")));
    }

    for ev in log.events() {
        let on_macro_track = matches!(
            ev.kind,
            EventKind::RegionReload | EventKind::MigrateSpan | EventKind::TwinPass
        );
        let (pid, tid) = match ev.macro_id {
            Some(m) if on_macro_track => (1usize, m),
            _ => (2usize, names.iter().position(|n| n == &ev.tenant).unwrap_or(0)),
        };
        let ph = if on_macro_track || ev.kind == EventKind::DispatchEnd { "X" } else { "i" };
        let mut j = Json::obj()
            .with("name", ev.kind.as_str())
            .with("cat", if ev.twin { "twin" } else { "fleet" })
            .with("ph", ph)
            .with("pid", pid)
            .with("tid", tid)
            .with("ts", ev.clock)
            .with("args", ev.to_json());
        if ph == "X" {
            j = j.with("dur", ev.cycles);
        } else {
            // Thread-scoped instant, so it renders on its track.
            j = j.with("s", "t");
        }
        out.push(j);
    }

    Json::obj()
        .with("traceEvents", out)
        .with("displayTimeUnit", "ms")
}

/// Recover the [`TraceEvent`] stream from a Chrome trace produced by
/// [`chrome_trace`] (metadata events are skipped; every other event's
/// `args` must parse).
pub fn events_from_chrome(j: &Json) -> Result<Vec<TraceEvent>> {
    let arr = j
        .get("traceEvents")
        .as_arr()
        .ok_or_else(|| anyhow!("not a Chrome trace: missing traceEvents array"))?;
    let mut out = Vec::new();
    for (i, e) in arr.iter().enumerate() {
        if e.get("ph").as_str() == Some("M") {
            continue;
        }
        let ev = TraceEvent::from_json(e.get("args"))
            .ok_or_else(|| anyhow!("traceEvents[{i}]: malformed args payload"))?;
        out.push(ev);
    }
    Ok(out)
}

fn prom_hist(out: &mut String, metric: &str, label_key: &str, label_val: &str, h: &CycleHistogram) {
    let mut cumulative = 0u64;
    for (i, b) in h.buckets().iter().enumerate() {
        if *b == 0 {
            continue;
        }
        cumulative += b;
        out.push_str(&format!(
            "{metric}_bucket{{{label_key}=\"{label_val}\",le=\"{}\"}} {cumulative}\n",
            CycleHistogram::bucket_ceiling(i)
        ));
    }
    out.push_str(&format!(
        "{metric}_bucket{{{label_key}=\"{label_val}\",le=\"+Inf\"}} {}\n",
        h.count()
    ));
    out.push_str(&format!("{metric}_sum{{{label_key}=\"{label_val}\"}} {}\n", h.sum()));
    out.push_str(&format!("{metric}_count{{{label_key}=\"{label_val}\"}} {}\n", h.count()));
}

/// Render a Prometheus text-exposition snapshot: per-kind event
/// counters (lifetime totals, unaffected by ring eviction), the drop
/// counter, an optional audit gauge, and the per-tenant / per-class
/// cycle histograms. Deterministic: fixed metric order, `BTreeMap`
/// label order, cumulative `le` buckets at power-of-two bounds.
pub fn prometheus_text(log: &TraceLog, hist: &Histograms, audit_pass: Option<bool>) -> String {
    let mut out = String::new();
    out.push_str("# TYPE cim_trace_events_total counter\n");
    for k in EventKind::ALL {
        out.push_str(&format!(
            "cim_trace_events_total{{kind=\"{}\"}} {}\n",
            k.as_str(),
            log.count(k)
        ));
    }
    out.push_str("# TYPE cim_trace_events_dropped_total counter\n");
    out.push_str(&format!("cim_trace_events_dropped_total {}\n", log.dropped()));
    if let Some(pass) = audit_pass {
        out.push_str("# TYPE cim_ledger_audit_pass gauge\n");
        out.push_str(&format!("cim_ledger_audit_pass {}\n", u64::from(pass)));
    }
    let lanes: [(&str, fn(&LaneHists) -> &CycleHistogram); 3] = [
        ("cim_queue_delay_cycles", |l| &l.queue_delay),
        ("cim_pass_cycles", |l| &l.pass),
        ("cim_reload_cycles", |l| &l.reload),
    ];
    for (metric, pick) in lanes {
        out.push_str(&format!("# TYPE {metric} histogram\n"));
        for (tenant, l) in hist.tenants() {
            prom_hist(&mut out, metric, "tenant", tenant, pick(l));
        }
        for (class, l) in hist.classes() {
            prom_hist(&mut out, metric, "class", class, pick(l));
        }
    }
    out
}

impl FleetTrace {
    /// Convenience: lock the bundle's log and export
    /// [`chrome_trace`] JSON.
    pub fn chrome(&self, num_macros: usize, tenants: &[String]) -> Json {
        chrome_trace(&self.log.lock().unwrap(), num_macros, tenants)
    }

    /// Convenience: lock the bundle's log + histograms and render
    /// [`prometheus_text`].
    pub fn prometheus(&self, audit_pass: Option<bool>) -> String {
        prometheus_text(&self.log.lock().unwrap(), &self.hist.lock().unwrap(), audit_pass)
    }
}

/// Render a fixed-width ASCII timeline, one row per macro, over the
/// trace's full virtual-clock span. Cell symbols: `R` reload, `M`
/// migration, `P` twin pass, `·` idle (twin-mirrored reload/migrate
/// events are skipped so each charge paints once). A cell covers
/// `span/width` cycles; an event paints every cell its
/// `[clock, clock+cycles]` range touches.
pub fn ascii_timeline(events: &[TraceEvent], width: usize) -> String {
    let width = width.max(8);
    let num_macros = events
        .iter()
        .filter_map(|e| e.macro_id)
        .max()
        .map(|m| m + 1)
        .unwrap_or(0);
    if num_macros == 0 {
        return "(no macro events in trace)\n".to_string();
    }
    let span = events.iter().map(|e| e.clock + e.cycles).max().unwrap_or(0).max(1);
    let mut rows = vec![vec!['·'; width]; num_macros];
    for ev in events {
        let sym = match ev.kind {
            EventKind::RegionReload if !ev.twin => 'R',
            EventKind::MigrateSpan if !ev.twin => 'M',
            EventKind::TwinPass => 'P',
            _ => continue,
        };
        let Some(m) = ev.macro_id else { continue };
        let lo =
            (((ev.clock as u128 * width as u128) / span as u128) as usize).min(width - 1);
        let hi = ((((ev.clock + ev.cycles.max(1)) as u128 * width as u128) / span as u128)
            as usize)
            .clamp(lo, width - 1);
        for cell in &mut rows[m][lo..=hi] {
            *cell = sym;
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "virtual clock 0..{span} cycles, {width} cells ({} cycles/cell)\n",
        (span + width as u64 - 1) / width as u64
    ));
    for (m, row) in rows.iter().enumerate() {
        out.push_str(&format!("macro {m:>3} |{}|\n", row.iter().collect::<String>()));
    }
    out.push_str("R reload · M migration · P twin pass\n");
    out
}

#[cfg(test)]
mod tests {
    use super::super::sink::TraceSink;
    use super::*;

    fn ev(clock: u64, kind: EventKind, tenant: &str, m: Option<usize>, cycles: u64) -> TraceEvent {
        TraceEvent {
            clock,
            kind,
            tenant: tenant.into(),
            macro_id: m,
            cycles,
            twin: false,
            detail: 1,
            class: None,
        }
    }

    fn sample_log() -> TraceLog {
        let mut log = TraceLog::new(64);
        log.record(&ev(0, EventKind::Admit, "hi", None, 900));
        log.record(&ev(0, EventKind::RegionReload, "hi", Some(0), 108));
        log.record(&ev(108, EventKind::DispatchEnd, "hi", None, 800));
        log.record(&ev(908, EventKind::MigrateSpan, "lo", Some(1), 82));
        log.record(&TraceEvent { twin: true, ..ev(908, EventKind::TwinPass, "lo", Some(1), 50) });
        log
    }

    #[test]
    fn chrome_trace_declares_every_track_and_roundtrips() {
        let log = sample_log();
        let j = chrome_trace(&log, 2, &["hi".to_string(), "lo".to_string()]);
        let parsed = Json::parse(&j.dump()).expect("exporter emits valid JSON");
        let arr = parsed.get("traceEvents").as_arr().unwrap();
        // 2 process_name + 2 macro tracks + 2 tenant tracks + 5 events.
        let metas: Vec<&Json> =
            arr.iter().filter(|e| e.get("ph").as_str() == Some("M")).collect();
        assert_eq!(metas.len(), 6);
        let back = events_from_chrome(&parsed).unwrap();
        let originals: Vec<TraceEvent> = log.events().cloned().collect();
        assert_eq!(back, originals, "args payloads recover the exact stream");
    }

    #[test]
    fn chrome_trace_adds_undeclared_tenants_deterministically() {
        let log = sample_log();
        let j = chrome_trace(&log, 2, &["hi".to_string()]);
        let txt = j.dump();
        assert!(txt.contains("tenant lo"), "log-only tenant still gets a track");
    }

    #[test]
    fn events_from_chrome_rejects_garbage() {
        assert!(events_from_chrome(&Json::obj()).is_err());
        let bad = Json::obj().with(
            "traceEvents",
            vec![Json::obj().with("ph", "X").with("args", Json::obj())],
        );
        assert!(events_from_chrome(&bad).is_err());
    }

    #[test]
    fn prometheus_snapshot_has_counters_and_cumulative_buckets() {
        let log = sample_log();
        let mut hist = Histograms::default();
        for e in log.events() {
            hist.record(e);
        }
        let text = prometheus_text(&log, &hist, Some(true));
        assert!(text.contains("cim_trace_events_total{kind=\"region_reload\"} 1\n"));
        assert!(text.contains("cim_trace_events_total{kind=\"evict\"} 0\n"));
        assert!(text.contains("cim_ledger_audit_pass 1\n"));
        assert!(text.contains("cim_reload_cycles_bucket{tenant=\"hi\",le=\"127\"} 1\n"));
        assert!(text.contains("cim_reload_cycles_bucket{tenant=\"hi\",le=\"+Inf\"} 1\n"));
        assert!(text.contains("cim_reload_cycles_sum{tenant=\"hi\"} 108\n"));
    }

    #[test]
    fn ascii_timeline_paints_macro_rows() {
        let events: Vec<TraceEvent> = sample_log().events().cloned().collect();
        let t = ascii_timeline(&events, 40);
        assert!(t.contains("macro   0 |"));
        assert!(t.contains("macro   1 |"));
        assert!(t.contains('R') && t.contains('M') && t.contains('P'));
        assert_eq!(ascii_timeline(&[], 40), "(no macro events in trace)\n");
    }
}
