//! Deterministic fleet observability: a bounded trace bus on the
//! virtual device-cycle clock, plus the aggregators and exporters built
//! on it.
//!
//! The fleet makes rich runtime decisions — QoS admission and
//! deferral, region hot-swaps, evictions, twin-verified migrations —
//! but a `FleetSnapshot` only shows the end-of-run totals. This module
//! records the decisions themselves as typed [`TraceEvent`]s, each
//! stamped with the same deterministic virtual clock the cycle ledgers
//! use, so "why was this request late" and "which tenant caused that
//! reload storm" become answerable — and two identical runs produce
//! byte-identical traces, making them CI-comparable artifacts.
//!
//! The pieces:
//! - [`TraceEvent`] / [`EventKind`] — the closed event schema (see the
//!   table in `docs/ARCHITECTURE.md`).
//! - [`TraceSink`] / [`SharedSink`] — where events go. Emitters
//!   (`Fleet`, `QosScheduler`) hold an `Option<SharedSink>`; `None`
//!   (the default) costs one branch per site and never constructs the
//!   event.
//! - [`TraceLog`] — bounded ring buffer with eviction-proof per-kind
//!   totals; [`Tee`] fans one stream to several sinks; [`NoopSink`]
//!   discards.
//! - [`Histograms`] — per-tenant / per-class log₂ [`CycleHistogram`]s
//!   of queue delay, pass time, and reload charges.
//! - [`LedgerAuditor`] — re-derives the four cycle ledgers (fleet ==
//!   per-macro == per-tenant == twin) from events alone and diffs them
//!   against the snapshot with a first-divergence report.
//! - [`chrome_trace`] / [`prometheus_text`] / [`ascii_timeline`] —
//!   deterministic exporters (`cim-adapt fleet --trace-out /
//!   --metrics-out`, `cim-adapt inspect --timeline`).
//! - [`FleetTrace`] — the standard bundle of log + histograms + audit
//!   behind one sink; see `FleetServer::start_with_trace`.

mod audit;
mod event;
mod export;
mod hist;
mod sink;

pub use audit::{AuditReport, LedgerAuditor};
pub use event::{EventKind, TraceEvent};
pub use export::{ascii_timeline, chrome_trace, events_from_chrome, prometheus_text};
pub use hist::{CycleHistogram, Histograms, LaneHists, HIST_BUCKETS};
pub use sink::{
    FleetTrace, NoopSink, ReorderSink, SharedSink, Tee, TraceLog, TraceSink,
    DEFAULT_TRACE_CAPACITY,
};

pub(crate) use sink::emit;
