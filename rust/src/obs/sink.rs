//! Sinks: where trace events go.
//!
//! The contract is deliberately tiny — [`TraceSink::record`] takes one
//! event by reference — so a sink can be a bounded ring buffer
//! ([`TraceLog`]), an online aggregator ([`super::Histograms`],
//! [`super::LedgerAuditor`]), or a fan-out ([`Tee`]) without the
//! emitters knowing. Emitters hold an `Option<SharedSink>`; `None`
//! (the default) means tracing is off and each emission site pays
//! exactly one branch — the event is never even constructed
//! (see [`emit`]).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use super::audit::LedgerAuditor;
use super::event::{EventKind, TraceEvent};
use super::hist::Histograms;

/// Default [`TraceLog`] ring capacity (events). 64Ki events bound the
/// log to a few MiB however long the run; `TraceLog::dropped` records
/// how many fell off the head.
pub const DEFAULT_TRACE_CAPACITY: usize = 1 << 16;

/// Receives trace events as they happen.
///
/// `Send + Debug` because the fleet (and the sink handle inside it)
/// crosses into the `FleetServer` dispatcher thread, and the fleet's
/// containers want to stay debug-printable. Sinks must not block or
/// panic: they run inline on the dispatch path under the shared mutex.
pub trait TraceSink: Send + std::fmt::Debug {
    /// Record one event. Called in emission order; `ev.clock` is
    /// non-decreasing across calls on one fleet.
    fn record(&mut self, ev: &TraceEvent);
}

/// A shareable, thread-safe handle to any sink. The emitting side
/// (`Fleet`, `QosScheduler`) and the exporting side (CLI, benches,
/// tests) each hold clones; the mutex serializes emission against
/// export.
pub type SharedSink = Arc<Mutex<dyn TraceSink>>;

/// Record an event into an optional sink, building it lazily.
///
/// This is the one emission helper every instrumented site uses: when
/// `sink` is `None` the closure never runs, so the traced-off hot path
/// pays a single branch — no `String` clones, no event construction.
/// Taking the field reference (rather than `&self`) keeps borrows
/// precise at call sites that hold live `&mut` borrows of sibling
/// fields.
pub(crate) fn emit(sink: &Option<SharedSink>, make: impl FnOnce() -> TraceEvent) {
    if let Some(s) = sink {
        let ev = make();
        s.lock().unwrap().record(&ev);
    }
}

/// A sink that discards everything — for measuring pure emission
/// overhead or satisfying an API that wants *a* sink.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoopSink;

impl TraceSink for NoopSink {
    fn record(&mut self, _ev: &TraceEvent) {}
}

/// Bounded, deterministic event ring buffer.
///
/// Keeps the most recent `capacity` events (older ones fall off the
/// head, counted in [`TraceLog::dropped`]) plus per-kind totals that
/// survive eviction — so Prometheus counters and bench counters stay
/// exact even when the ring wrapped.
#[derive(Debug)]
pub struct TraceLog {
    cap: usize,
    events: VecDeque<TraceEvent>,
    counts: [u64; EventKind::ALL.len()],
    dropped: u64,
}

impl TraceLog {
    /// A ring holding at most `capacity` events (minimum 1).
    pub fn new(capacity: usize) -> TraceLog {
        TraceLog {
            cap: capacity.max(1),
            events: VecDeque::with_capacity(capacity.max(1).min(DEFAULT_TRACE_CAPACITY)),
            counts: [0; EventKind::ALL.len()],
            dropped: 0,
        }
    }

    /// The retained events, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter()
    }

    /// Number of events currently retained (≤ capacity).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when nothing has been retained.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events evicted off the head because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Lifetime count of `kind` events recorded — NOT affected by ring
    /// eviction.
    pub fn count(&self, kind: EventKind) -> u64 {
        self.counts[kind.index()]
    }

    /// Lifetime count of all events recorded.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

impl TraceSink for TraceLog {
    fn record(&mut self, ev: &TraceEvent) {
        self.counts[ev.kind.index()] += 1;
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev.clone());
    }
}

/// Fans one event stream out to several sinks, in order.
#[derive(Debug)]
pub struct Tee {
    sinks: Vec<SharedSink>,
}

impl Tee {
    /// A tee over `sinks`; each recorded event reaches every sink.
    pub fn new(sinks: Vec<SharedSink>) -> Tee {
        Tee { sinks }
    }
}

impl TraceSink for Tee {
    fn record(&mut self, ev: &TraceEvent) {
        for s in &self.sinks {
            s.lock().unwrap().record(ev);
        }
    }
}

/// The standard tracing bundle: ring-buffer log + per-tenant histograms
/// + online ledger audit, all fed from one [`Tee`].
///
/// Lifecycle: build one, hand [`FleetTrace::sink`] to
/// `Fleet::set_trace` (or `FleetServer::start_with_trace`), run the
/// scenario, then read/lock the three handles to export — the caller's
/// `Arc` clones stay valid after the fleet (and its dispatcher thread)
/// shut down.
#[derive(Debug)]
pub struct FleetTrace {
    /// The bounded event ring (export via `chrome_trace` /
    /// `ascii_timeline`, replay via `LedgerAuditor::replay`).
    pub log: Arc<Mutex<TraceLog>>,
    /// Per-tenant / per-class cycle histograms.
    pub hist: Arc<Mutex<Histograms>>,
    /// The online four-ledger audit (call `verify` against the final
    /// `FleetSnapshot`).
    pub audit: Arc<Mutex<LedgerAuditor>>,
}

impl FleetTrace {
    /// A bundle whose log ring holds `capacity` events.
    pub fn new(capacity: usize) -> FleetTrace {
        FleetTrace {
            log: Arc::new(Mutex::new(TraceLog::new(capacity))),
            hist: Arc::new(Mutex::new(Histograms::default())),
            audit: Arc::new(Mutex::new(LedgerAuditor::default())),
        }
    }

    /// A fresh shared sink feeding all three aggregators.
    pub fn sink(&self) -> SharedSink {
        let log: SharedSink = self.log.clone();
        let hist: SharedSink = self.hist.clone();
        let audit: SharedSink = self.audit.clone();
        Arc::new(Mutex::new(Tee::new(vec![log, hist, audit])))
    }
}

impl Default for FleetTrace {
    fn default() -> FleetTrace {
        FleetTrace::new(DEFAULT_TRACE_CAPACITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(clock: u64, kind: EventKind) -> TraceEvent {
        TraceEvent {
            clock,
            kind,
            tenant: "t".into(),
            macro_id: None,
            cycles: clock,
            twin: false,
            detail: 0,
            class: None,
        }
    }

    #[test]
    fn ring_evicts_oldest_but_counts_survive() {
        let mut log = TraceLog::new(4);
        for i in 0..10 {
            log.record(&ev(i, EventKind::Admit));
        }
        assert_eq!(log.len(), 4);
        assert_eq!(log.dropped(), 6);
        assert_eq!(log.count(EventKind::Admit), 10);
        assert_eq!(log.total(), 10);
        let clocks: Vec<u64> = log.events().map(|e| e.clock).collect();
        assert_eq!(clocks, vec![6, 7, 8, 9], "oldest fall off the head");
    }

    #[test]
    fn emit_skips_event_construction_when_off() {
        let mut built = false;
        emit(&None, || {
            built = true;
            ev(0, EventKind::Admit)
        });
        assert!(!built, "no sink, no event");
        let trace = FleetTrace::new(8);
        let sink = Some(trace.sink());
        emit(&sink, || ev(1, EventKind::Reject));
        assert_eq!(trace.log.lock().unwrap().count(EventKind::Reject), 1);
    }

    #[test]
    fn tee_fans_out_to_every_sink() {
        let a: Arc<Mutex<TraceLog>> = Arc::new(Mutex::new(TraceLog::new(4)));
        let b: Arc<Mutex<TraceLog>> = Arc::new(Mutex::new(TraceLog::new(4)));
        let (sa, sb): (SharedSink, SharedSink) = (a.clone(), b.clone());
        let mut tee = Tee::new(vec![sa, sb]);
        tee.record(&ev(3, EventKind::Evict));
        assert_eq!(a.lock().unwrap().total(), 1);
        assert_eq!(b.lock().unwrap().total(), 1);
    }
}
