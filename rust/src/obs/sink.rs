//! Sinks: where trace events go.
//!
//! The contract is deliberately tiny — [`TraceSink::record`] takes one
//! event by reference — so a sink can be a bounded ring buffer
//! ([`TraceLog`]), an online aggregator ([`super::Histograms`],
//! [`super::LedgerAuditor`]), or a fan-out ([`Tee`]) without the
//! emitters knowing. Emitters hold an `Option<SharedSink>`; `None`
//! (the default) means tracing is off and each emission site pays
//! exactly one branch — the event is never even constructed
//! (see [`emit`]).

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

use super::audit::LedgerAuditor;
use super::event::{EventKind, TraceEvent};
use super::hist::Histograms;

/// Default [`TraceLog`] ring capacity (events). 64Ki events bound the
/// log to a few MiB however long the run; `TraceLog::dropped` records
/// how many fell off the head.
pub const DEFAULT_TRACE_CAPACITY: usize = 1 << 16;

/// Receives trace events as they happen.
///
/// `Send + Debug` because the fleet (and the sink handle inside it)
/// crosses into the `FleetServer` dispatcher thread, and the fleet's
/// containers want to stay debug-printable. Sinks must not block or
/// panic: they run inline on the dispatch path under the shared mutex.
pub trait TraceSink: Send + std::fmt::Debug {
    /// Record one event. Called in emission order; `ev.clock` is
    /// non-decreasing across calls on one fleet.
    fn record(&mut self, ev: &TraceEvent);
}

/// A shareable, thread-safe handle to any sink. The emitting side
/// (`Fleet`, `QosScheduler`) and the exporting side (CLI, benches,
/// tests) each hold clones; the mutex serializes emission against
/// export.
pub type SharedSink = Arc<Mutex<dyn TraceSink>>;

/// Record an event into an optional sink, building it lazily.
///
/// This is the one emission helper every instrumented site uses: when
/// `sink` is `None` the closure never runs, so the traced-off hot path
/// pays a single branch — no `String` clones, no event construction.
/// Taking the field reference (rather than `&self`) keeps borrows
/// precise at call sites that hold live `&mut` borrows of sibling
/// fields.
pub(crate) fn emit(sink: &Option<SharedSink>, make: impl FnOnce() -> TraceEvent) {
    if let Some(s) = sink {
        let ev = make();
        s.lock().unwrap().record(&ev);
    }
}

/// A sink that discards everything — for measuring pure emission
/// overhead or satisfying an API that wants *a* sink.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoopSink;

impl TraceSink for NoopSink {
    fn record(&mut self, _ev: &TraceEvent) {}
}

/// Bounded, deterministic event ring buffer.
///
/// Keeps the most recent `capacity` events (older ones fall off the
/// head, counted in [`TraceLog::dropped`]) plus per-kind totals that
/// survive eviction — so Prometheus counters and bench counters stay
/// exact even when the ring wrapped.
#[derive(Debug)]
pub struct TraceLog {
    cap: usize,
    events: VecDeque<TraceEvent>,
    counts: [u64; EventKind::ALL.len()],
    dropped: u64,
}

impl TraceLog {
    /// A ring holding at most `capacity` events (minimum 1).
    pub fn new(capacity: usize) -> TraceLog {
        TraceLog {
            cap: capacity.max(1),
            events: VecDeque::with_capacity(capacity.max(1).min(DEFAULT_TRACE_CAPACITY)),
            counts: [0; EventKind::ALL.len()],
            dropped: 0,
        }
    }

    /// The retained events, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter()
    }

    /// Number of events currently retained (≤ capacity).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when nothing has been retained.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events evicted off the head because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Lifetime count of `kind` events recorded — NOT affected by ring
    /// eviction.
    pub fn count(&self, kind: EventKind) -> u64 {
        self.counts[kind.index()]
    }

    /// Lifetime count of all events recorded.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

impl TraceSink for TraceLog {
    fn record(&mut self, ev: &TraceEvent) {
        self.counts[ev.kind.index()] += 1;
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev.clone());
    }
}

/// Fans one event stream out to several sinks, in order.
#[derive(Debug)]
pub struct Tee {
    sinks: Vec<SharedSink>,
}

impl Tee {
    /// A tee over `sinks`; each recorded event reaches every sink.
    pub fn new(sinks: Vec<SharedSink>) -> Tee {
        Tee { sinks }
    }
}

impl TraceSink for Tee {
    fn record(&mut self, ev: &TraceEvent) {
        for s in &self.sinks {
            s.lock().unwrap().record(ev);
        }
    }
}

/// Reorders event segments back into deterministic op order.
///
/// The concurrent runtime ([`crate::runtime::ConcurrentFleet`]) overlaps
/// batches: op *k*'s finish events are emitted after op *k+1*'s begin
/// events. Sequential replays — and the [`LedgerAuditor`]'s
/// clock-monotonicity check — want the stream in op order, with each
/// op's begin and finish contiguous. The driver brackets every emission
/// burst in a numbered *slot* (`begin_segment`/`end_segment`, slots
/// numbered in op order) and `seal`s a slot when its op has fully
/// finished; sealed slots flush to the inner sink strictly in slot
/// order, so the merged stream is byte-identical to what the sequential
/// driver would have produced.
///
/// Events recorded outside any open segment pass straight through.
/// All emission happens on the driver thread — workers never touch the
/// sink — so no cross-thread buffering is needed, only re-sequencing.
#[derive(Debug)]
pub struct ReorderSink {
    inner: SharedSink,
    slots: BTreeMap<u64, Slot>,
    current: Option<u64>,
    next_flush: u64,
}

#[derive(Debug, Default)]
struct Slot {
    events: Vec<TraceEvent>,
    sealed: bool,
}

impl ReorderSink {
    /// A reorder buffer in front of `inner`.
    pub fn new(inner: SharedSink) -> ReorderSink {
        ReorderSink {
            inner,
            slots: BTreeMap::new(),
            current: None,
            next_flush: 0,
        }
    }

    /// Route subsequent events into slot `seq` (creating it if new —
    /// a finish burst re-opens the slot its begin burst created).
    pub fn begin_segment(&mut self, seq: u64) {
        self.slots.entry(seq).or_default();
        self.current = Some(seq);
    }

    /// Stop routing into the current slot (events pass through again).
    pub fn end_segment(&mut self) {
        self.current = None;
    }

    /// Mark slot `seq` complete and flush every leading sealed slot, in
    /// slot order, to the inner sink.
    pub fn seal(&mut self, seq: u64) {
        if let Some(s) = self.slots.get_mut(&seq) {
            s.sealed = true;
        }
        while self
            .slots
            .get(&self.next_flush)
            .map(|s| s.sealed)
            .unwrap_or(false)
        {
            let slot = self.slots.remove(&self.next_flush).expect("checked above");
            let mut inner = self.inner.lock().unwrap();
            for ev in &slot.events {
                inner.record(ev);
            }
            self.next_flush += 1;
        }
    }

    /// Events buffered in unsealed (or not-yet-flushable) slots.
    pub fn buffered(&self) -> usize {
        self.slots.values().map(|s| s.events.len()).sum()
    }
}

impl TraceSink for ReorderSink {
    fn record(&mut self, ev: &TraceEvent) {
        match self.current {
            Some(seq) => self
                .slots
                .get_mut(&seq)
                .expect("begin_segment created the slot")
                .events
                .push(ev.clone()),
            None => self.inner.lock().unwrap().record(ev),
        }
    }
}

/// The standard tracing bundle: ring-buffer log + per-tenant histograms
/// + online ledger audit, all fed from one [`Tee`].
///
/// Lifecycle: build one, hand [`FleetTrace::sink`] to
/// `Fleet::set_trace` (or `FleetServer::start_with_trace`), run the
/// scenario, then read/lock the three handles to export — the caller's
/// `Arc` clones stay valid after the fleet (and its dispatcher thread)
/// shut down.
#[derive(Debug)]
pub struct FleetTrace {
    /// The bounded event ring (export via `chrome_trace` /
    /// `ascii_timeline`, replay via `LedgerAuditor::replay`).
    pub log: Arc<Mutex<TraceLog>>,
    /// Per-tenant / per-class cycle histograms.
    pub hist: Arc<Mutex<Histograms>>,
    /// The online four-ledger audit (call `verify` against the final
    /// `FleetSnapshot`).
    pub audit: Arc<Mutex<LedgerAuditor>>,
}

impl FleetTrace {
    /// A bundle whose log ring holds `capacity` events.
    pub fn new(capacity: usize) -> FleetTrace {
        FleetTrace {
            log: Arc::new(Mutex::new(TraceLog::new(capacity))),
            hist: Arc::new(Mutex::new(Histograms::default())),
            audit: Arc::new(Mutex::new(LedgerAuditor::default())),
        }
    }

    /// A fresh shared sink feeding all three aggregators.
    pub fn sink(&self) -> SharedSink {
        let log: SharedSink = self.log.clone();
        let hist: SharedSink = self.hist.clone();
        let audit: SharedSink = self.audit.clone();
        Arc::new(Mutex::new(Tee::new(vec![log, hist, audit])))
    }
}

impl Default for FleetTrace {
    fn default() -> FleetTrace {
        FleetTrace::new(DEFAULT_TRACE_CAPACITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(clock: u64, kind: EventKind) -> TraceEvent {
        TraceEvent {
            clock,
            kind,
            tenant: "t".into(),
            macro_id: None,
            cycles: clock,
            twin: false,
            detail: 0,
            class: None,
        }
    }

    #[test]
    fn ring_evicts_oldest_but_counts_survive() {
        let mut log = TraceLog::new(4);
        for i in 0..10 {
            log.record(&ev(i, EventKind::Admit));
        }
        assert_eq!(log.len(), 4);
        assert_eq!(log.dropped(), 6);
        assert_eq!(log.count(EventKind::Admit), 10);
        assert_eq!(log.total(), 10);
        let clocks: Vec<u64> = log.events().map(|e| e.clock).collect();
        assert_eq!(clocks, vec![6, 7, 8, 9], "oldest fall off the head");
    }

    #[test]
    fn emit_skips_event_construction_when_off() {
        let mut built = false;
        emit(&None, || {
            built = true;
            ev(0, EventKind::Admit)
        });
        assert!(!built, "no sink, no event");
        let trace = FleetTrace::new(8);
        let sink = Some(trace.sink());
        emit(&sink, || ev(1, EventKind::Reject));
        assert_eq!(trace.log.lock().unwrap().count(EventKind::Reject), 1);
    }

    #[test]
    fn reorder_sink_flushes_sealed_slots_in_order() {
        let log: Arc<Mutex<TraceLog>> = Arc::new(Mutex::new(TraceLog::new(16)));
        let inner: SharedSink = log.clone();
        let mut r = ReorderSink::new(inner);
        // Op 0 begin, op 1 begin+seal (a synchronous op), op 0 finish+seal
        // — the overlapped emission order the concurrent driver produces.
        r.begin_segment(0);
        r.record(&ev(10, EventKind::DispatchStart));
        r.end_segment();
        r.begin_segment(1);
        r.record(&ev(20, EventKind::Admit));
        r.end_segment();
        r.seal(1);
        assert_eq!(log.lock().unwrap().total(), 0, "slot 0 still open blocks slot 1");
        assert_eq!(r.buffered(), 2);
        r.begin_segment(0);
        r.record(&ev(10, EventKind::DispatchEnd));
        r.end_segment();
        r.seal(0);
        assert_eq!(r.buffered(), 0);
        let kinds: Vec<EventKind> = log.lock().unwrap().events().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![EventKind::DispatchStart, EventKind::DispatchEnd, EventKind::Admit],
            "op 0's begin+finish flush contiguously before op 1"
        );
    }

    #[test]
    fn reorder_sink_passes_through_outside_segments() {
        let log: Arc<Mutex<TraceLog>> = Arc::new(Mutex::new(TraceLog::new(16)));
        let inner: SharedSink = log.clone();
        let mut r = ReorderSink::new(inner);
        r.record(&ev(1, EventKind::Admit));
        assert_eq!(log.lock().unwrap().total(), 1);
    }

    #[test]
    fn tee_fans_out_to_every_sink() {
        let a: Arc<Mutex<TraceLog>> = Arc::new(Mutex::new(TraceLog::new(4)));
        let b: Arc<Mutex<TraceLog>> = Arc::new(Mutex::new(TraceLog::new(4)));
        let (sa, sb): (SharedSink, SharedSink) = (a.clone(), b.clone());
        let mut tee = Tee::new(vec![sa, sb]);
        tee.record(&ev(3, EventKind::Evict));
        assert_eq!(a.lock().unwrap().total(), 1);
        assert_eq!(b.lock().unwrap().total(), 1);
    }
}
