//! Regression tests pinning the cost model to the paper's published
//! numbers — every deterministic cell of Tables III–V, plus the macro
//! usage values of Tables II/III and the Table VI operating points.

use cim_adapt::arch::{by_name, resnet18, vgg16, vgg9};
use cim_adapt::baselines::{eupq_point, xpert_point};
use cim_adapt::config::{MacroSpec, MorphConfig};
use cim_adapt::latency::cost::macro_usage;
use cim_adapt::latency::model_cost;
use cim_adapt::morph::flow::morph_flow_synthetic;

fn spec() -> MacroSpec {
    MacroSpec::default()
}

/// Table III baseline row, exactly.
#[test]
fn table3_vgg9_baseline_row() {
    let c = model_cost(&vgg9(), &spec());
    assert_eq!(c.params_m(), 9.218);
    assert_eq!(c.bls, 38_592);
    assert_eq!(c.macs, 724_992);
    assert_eq!(c.psum_storage, 163_840);
    assert_eq!(c.load_weight_latency, 38_656);
    assert_eq!(c.computing_latency, 14_696);
}

/// Table IV baseline row, exactly.
#[test]
fn table4_vgg16_baseline_row() {
    let c = model_cost(&vgg16(), &spec());
    assert_eq!(c.params_m(), 14.710);
    assert_eq!(c.bls, 61_440);
    assert_eq!(c.macs, 1_443_840);
    assert_eq!(c.psum_storage, 196_608);
    assert_eq!(c.load_weight_latency, 61_440);
    assert_eq!(c.computing_latency, 31_300);
}

/// Table V baseline row, exactly.
#[test]
fn table5_resnet18_baseline_row() {
    let c = model_cost(&resnet18(), &spec());
    assert_eq!(c.params_m(), 10.987);
    assert_eq!(c.bls, 46_400);
    assert_eq!(c.macs, 690_176);
    assert_eq!(c.psum_storage, 65_536);
    assert_eq!(c.load_weight_latency, 46_592);
    assert_eq!(c.computing_latency, 16_860);
}

/// Table III morphed-row *macro usage* column: the paper's params +
/// budget pairs reproduce the printed usage percentages exactly under
/// `usage = params / (target_bl · 256)`.
#[test]
fn table3_macro_usage_column() {
    let cases = [
        (1.971e6, 8192usize, 93.98),
        (0.924e6, 4096, 88.12),
        (0.210e6, 1024, 80.11),
        (0.098e6, 512, 74.77),
    ];
    for (params, bl, expect) in cases {
        let u = macro_usage(params as usize, bl, &spec()) * 100.0;
        assert!((u - expect).abs() < 0.05, "@{bl}: {u:.2} vs {expect}");
    }
}

/// Table IV / V usage columns likewise (paper rounds params to 3
/// decimals, so allow a slightly wider band).
#[test]
fn table4_5_macro_usage_columns() {
    let cases = [
        (1.983e6, 8192usize, 94.54), // VGG16
        (0.952e6, 4096, 90.83),
        (0.203e6, 1024, 77.58),
        (0.088e6, 512, 67.07),
        (1.804e6, 8192, 86.01), // ResNet18
        (0.829e6, 4096, 78.77),
        (0.132e6, 1024, 50.71),
        (0.033e6, 512, 25.37),
    ];
    for (params, bl, expect) in cases {
        let u = macro_usage(params as usize, bl, &spec()) * 100.0;
        // ±0.4: the paper prints params at 3 decimals (e.g. its 0.132M /
        // 50.71% ResNet row implies 132,934 actual params).
        assert!(
            (u - expect).abs() < 0.4,
            "params={params} @{bl}: {u:.2} vs {expect}"
        );
    }
}

/// The morphed rows' *shape*: reductions fall in the paper's ranges.
/// (Exact morphed channel configs are not published; our morphing engine
/// must land in the same regime — DESIGN.md §4.)
#[test]
fn morphed_rows_reduction_shape() {
    let s = spec();
    for (model, base_load) in [("vgg9", 38_656usize), ("vgg16", 61_440), ("resnet18", 46_592)] {
        let arch = by_name(model).unwrap();
        let base = model_cost(&arch, &s);
        for target in [8192usize, 4096, 1024, 512] {
            let cfg = MorphConfig {
                target_bl: target,
                ..MorphConfig::default()
            };
            let out = morph_flow_synthetic(&arch, &s, &cfg, 0.4, 11);
            // Load-latency cut 79–99% across the table (paper text).
            let load_cut = 1.0 - out.cost.load_weight_latency as f64 / base_load as f64;
            assert!(load_cut >= 0.75, "{model}@{target}: load cut {load_cut:.2}");
            // Compute latency must not increase.
            assert!(
                out.cost.computing_latency <= base.computing_latency,
                "{model}@{target}: compute grew"
            );
            // Compression ≥ 75% everywhere (paper: −79% .. −99.6%).
            let p_cut = 1.0 - out.cost.params as f64 / base.params as f64;
            assert!(p_cut >= 0.75, "{model}@{target}: params cut {p_cut:.2}");
        }
    }
}

/// Paper claim: "enhances CIM array utilization to 90%" / "up to 94.54%"
/// — our morph at 8192/4096 must reach ≥85% on the VGG models.
#[test]
fn high_usage_at_large_budgets() {
    let s = spec();
    for model in ["vgg9", "vgg16"] {
        let arch = by_name(model).unwrap();
        for target in [8192usize, 4096] {
            let cfg = MorphConfig {
                target_bl: target,
                ..MorphConfig::default()
            };
            let out = morph_flow_synthetic(&arch, &s, &cfg, 0.4, 11);
            assert!(
                out.macro_usage >= 0.85,
                "{model}@{target}: usage {:.3}",
                out.macro_usage
            );
        }
    }
}

/// Table VI fixed columns.
#[test]
fn table6_operating_points() {
    let e1 = eupq_point("resnet18");
    assert_eq!(e1.activated_wordlines, 16);
    assert_eq!(e1.memory_cell_bits, 1);
    assert_eq!(e1.compression_pct, -87.50);
    let x = xpert_point();
    assert_eq!(x.activated_wordlines, 64);
    assert_eq!(x.bits.0, 8.0);
    // Ours activates all 256 wordlines: 16× / 4× more than E-UPQ / XPert.
    assert_eq!(256 / e1.activated_wordlines, 16);
    assert_eq!(256 / x.activated_wordlines, 4);
}

/// Paper conclusion: "achieves up to 93% compression". VGG16 @ 4096 is
/// the −93.53% row; our flow should reach ≥90% there.
#[test]
fn headline_compression_vgg16() {
    let s = spec();
    let arch = vgg16();
    let base = model_cost(&arch, &s);
    let cfg = MorphConfig {
        target_bl: 4096,
        ..MorphConfig::default()
    };
    let out = morph_flow_synthetic(&arch, &s, &cfg, 0.4, 11);
    let cut = 1.0 - out.cost.params as f64 / base.params as f64;
    assert!(cut >= 0.90, "compression {cut:.3}");
}

/// ResNet18's usage penalty at small budgets (paper: 25.37% at 512) —
/// our flow should show the same qualitative collapse relative to VGG.
#[test]
fn resnet_usage_collapses_at_512() {
    let s = spec();
    let cfg = MorphConfig {
        target_bl: 512,
        ..MorphConfig::default()
    };
    let r = morph_flow_synthetic(&resnet18(), &s, &cfg, 0.4, 11);
    let v = morph_flow_synthetic(&vgg9(), &s, &cfg, 0.4, 11);
    assert!(
        r.macro_usage < v.macro_usage,
        "resnet {:.3} should trail vgg9 {:.3} at 512 BLs (more layers → more ragged columns)",
        r.macro_usage,
        v.macro_usage
    );
}
