//! Integration: multi-tenant fleet serving — 3+ adapted models over a
//! 4-macro sim fleet, forced eviction + hot-swap behavior, and
//! conservation of reload accounting (fleet-level reload cycles ==
//! Σ per-macro `MacroStats::load_cycles`).

use cim_adapt::arch::by_name;
use cim_adapt::cim::MacroStats;
use cim_adapt::config::{ExecutionMode, FleetConfig, MacroSpec, MorphConfig};
use cim_adapt::data::SynthCifar;
use cim_adapt::fleet::{EvictionPolicy, Fleet, FleetServer, FleetSnapshot};
use cim_adapt::mapping::{pack_model, FitPolicyKind};
use cim_adapt::morph::flow::morph_flow_synthetic;

const FLEET_MACROS: usize = 4;

fn spec() -> MacroSpec {
    MacroSpec::default()
}

/// Morph `model` to a 512-bitline budget: each tenant then needs ≥1 and
/// ≤2 macros, so three tenants (total demand ≥ 3) can all be registered
/// on a 4-macro fleet while their aggregate demand forces evictions.
fn tenant(model: &str, seed: u64) -> cim_adapt::arch::ModelArch {
    let out = morph_flow_synthetic(
        &by_name(model).unwrap(),
        &spec(),
        &MorphConfig {
            target_bl: 512,
            ..MorphConfig::default()
        },
        0.4,
        seed,
    );
    out.arch
}

fn cfg(policy: EvictionPolicy) -> FleetConfig {
    FleetConfig {
        num_macros: FLEET_MACROS,
        max_batch: 4,
        batch_timeout_us: 300,
        policy,
        ..FleetConfig::default()
    }
}

fn img(k: usize) -> Vec<f32> {
    SynthCifar::sample(k % 10, k as u64).data
}

#[test]
fn three_models_on_four_macros_with_eviction_and_conservation() {
    let h = FleetServer::start(&cfg(EvictionPolicy::Lru), &spec());
    let tenants = ["vgg9", "vgg16", "resnet18"];
    let mut demand = 0usize;
    for (i, m) in tenants.iter().enumerate() {
        let arch = tenant(m, 11 + i as u64);
        let macros = pack_model(&arch, &spec()).num_macros;
        assert!(
            macros <= FLEET_MACROS,
            "{m}: morphed tenant must fit the fleet ({macros} macros)"
        );
        demand += macros;
        h.register(m, arch, false).unwrap();
    }
    assert!(
        demand > FLEET_MACROS,
        "aggregate demand ({demand}) must exceed the fleet to force evictions"
    );

    // Interleaved tagged requests across all three tenants.
    let total = 90usize;
    let mut tickets = Vec::with_capacity(total);
    for k in 0..total {
        let model = tenants[k % tenants.len()];
        tickets.push(h.submit(model, img(k)).unwrap());
    }
    for t in tickets {
        let r = t.wait().unwrap();
        assert!(r.class < 10);
        assert!(r.device_cycles > 0);
    }

    let (m, snap) = h.shutdown();
    assert_eq!(m.completed, total as u64);
    assert_eq!(m.submitted, total as u64);

    // At least one forced eviction and the hot-swaps that follow.
    assert!(snap.evictions >= 1, "evictions: {}", snap.evictions);
    assert!(snap.hot_swaps >= tenants.len() as u64 + 1, "hot_swaps: {}", snap.hot_swaps);

    // Conservation: fleet-level reload cycles equal the per-macro sum
    // and the per-tenant attribution sum, and the Metrics reload-event
    // count matches the same cycle total.
    assert!(snap.reload_cycles > 0);
    assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
    assert_eq!(snap.reload_cycles, snap.tenant_load_cycles());
    assert_eq!(
        m.weight_reloads * spec().load_cycles_per_macro as u64,
        snap.reload_cycles,
        "Metrics reload events must account for the same cycles"
    );
    // The eviction counter flows through the shared Metrics path too.
    assert_eq!(m.evictions, snap.evictions);
}

#[test]
fn coresident_tenants_share_a_macro_with_exact_attribution() {
    // Two fractional-macro tenants on a 2-macro co-resident fleet: both
    // end up on macro 0's columns, partial swaps cost fewer cycles than a
    // whole-macro reload, and per-tenant MacroStats attribution still
    // sums to the fleet total.
    let spec_ = spec();
    let cfg = FleetConfig {
        num_macros: 2,
        coresident: true,
        ..cfg(EvictionPolicy::Lru)
    };
    let h = FleetServer::start(&cfg, &spec_);
    let small_a = by_name("vgg9").unwrap().scaled(0.04);
    let small_b = by_name("vgg9").unwrap().scaled(0.03);
    let na = pack_model(&small_a, &spec_).total_bls;
    let nb = pack_model(&small_b, &spec_).total_bls;
    assert!(
        na + nb <= spec_.bitlines,
        "tenants must fit one macro together ({na}+{nb})"
    );
    h.register("a", small_a, false).unwrap();
    h.register("b", small_b, false).unwrap();

    let total = 40usize;
    let mut tickets = Vec::with_capacity(total);
    for k in 0..total {
        let model = ["a", "b"][k % 2];
        tickets.push(h.submit(model, img(k)).unwrap());
    }
    for t in tickets {
        let r = t.wait().unwrap();
        assert!(r.class < 10);
    }
    let (m, snap) = h.shutdown();
    assert_eq!(m.completed, total as u64);

    // Both tenants stayed resident on the shared macro: one partial swap
    // each, never an eviction.
    assert_eq!(snap.evictions, 0, "co-residents never evict each other");
    assert_eq!(m.evictions, snap.evictions);
    assert_eq!(snap.resident.len(), 2);
    for p in &snap.resident {
        assert_eq!(p.macros(), vec![0], "both tenants live on macro 0");
    }
    // Regions are disjoint and cover exactly the occupied columns.
    let all_regions: Vec<_> = snap.resident.iter().flat_map(|p| p.regions.clone()).collect();
    for (i, a) in all_regions.iter().enumerate() {
        for b in &all_regions[i + 1..] {
            assert!(!a.overlaps(b), "{a:?} overlaps {b:?}");
        }
    }
    assert_eq!(snap.occupied_bls, vec![na + nb, 0]);
    assert!(snap.utilization() > 0.0);

    // Partial swaps: total reload cycles are the tenants' column counts,
    // strictly below the whole-macro charge for the same two swaps.
    assert_eq!(snap.reload_cycles, (na + nb) as u64);
    assert!(snap.reload_cycles < 2 * spec_.load_cycles_per_macro as u64);

    // Per-tenant MacroStats attribution sums to the fleet total.
    assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
    assert_eq!(snap.reload_cycles, snap.tenant_load_cycles());
    assert_eq!(snap.tenant_aggregate(), snap.aggregate());
    let by_name_stats: std::collections::BTreeMap<_, _> =
        snap.tenant_stats.iter().cloned().collect();
    assert_eq!(by_name_stats["a"].load_cycles, na as u64);
    assert_eq!(by_name_stats["b"].load_cycles, nb as u64);
    assert_eq!(
        by_name_stats["a"].compute_cycles + by_name_stats["b"].compute_cycles,
        snap.aggregate().compute_cycles
    );
}

#[test]
fn twin_and_analytic_ledgers_agree_on_fragmented_coresident_swap() {
    // The acceptance scenario for twin-driven execution: a churned
    // 1-macro co-resident pool fragments tenant c's placement into two
    // regions, the twin materializes both spans with real weight columns,
    // and the twin's charged load cycles equal the analytic ledger's
    // per-region reload-cycle sum *exactly* — in the twin fleet and
    // against an identical analytically-executed fleet.
    let spec_ = spec();
    let mk = |execution: ExecutionMode| {
        let cfg = FleetConfig {
            num_macros: 1,
            coresident: true,
            execution,
            ..cfg(EvictionPolicy::Lru)
        };
        let mut fleet = Fleet::new(&cfg, &spec_);
        // 108 + 82 + 139 BLs on a 256-column macro: c forces a's eviction
        // and lands fragmented around the surviving b.
        fleet.register("a", by_name("vgg9").unwrap().scaled(0.04), false).unwrap();
        fleet.register("b", by_name("vgg9").unwrap().scaled(0.03), false).unwrap();
        fleet.register("c", by_name("vgg9").unwrap().scaled(0.05), false).unwrap();
        let batch = vec![img(0)];
        fleet.serve_batch("a", &batch).unwrap();
        fleet.serve_batch("b", &batch).unwrap();
        let oc = fleet.serve_batch("c", &batch).unwrap();
        assert_eq!(oc.evicted, vec!["a".to_string()]);
        (fleet, oc)
    };

    let (mut twin_fleet, oc) = mk(ExecutionMode::Twin);
    let (na, nb, nc) = (108u64, 82, 139);
    assert_eq!(
        twin_fleet.registry().get("c").unwrap().bls_needed() as u64,
        nc
    );
    // c's placement is genuinely fragmented: two disjoint spans.
    let placed = twin_fleet.placed_mapping("c").unwrap().clone();
    assert_eq!(placed.spans.len(), 2, "churn must fragment c: {:?}", placed.spans);
    assert_eq!(oc.reload_events, 2, "one load event per span");
    assert_eq!(oc.reload_cycles, nc, "region cycles sum to the footprint");
    assert!(twin_fleet.is_resident("b"), "co-resident b survives");

    let snap = twin_fleet.snapshot();
    assert_eq!(snap.reload_cycles, na + nb + nc);
    // The headline agreement: twin charge == analytic ledger, exactly.
    assert_eq!(snap.twin_load_cycles(), snap.reload_cycles);
    assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
    assert_eq!(snap.reload_cycles, snap.tenant_load_cycles());
    assert_eq!(
        snap.twin_stats.iter().map(|s| s.reloads).sum::<u64>(),
        4,
        "a + b + two spans of c"
    );

    // The spans hold c's real weight columns (readback across fragments).
    let weights = twin_fleet.registry().get("c").unwrap().weights.clone().unwrap();
    for (bl, col) in weights.columns.iter().enumerate() {
        let (mac, local) = placed.locate(bl);
        assert_eq!(&twin_fleet.twin_macros()[mac].read_column(local), col, "column {bl}");
    }

    // Twin inference over the fragmented layout is deterministic and
    // reachable through both the batch path and infer_twin.
    let image = img(7);
    let (class, logits) = twin_fleet.infer_twin("c", &image).unwrap();
    let out = twin_fleet.serve_batch("c", &[image]).unwrap();
    assert_eq!(out.classes[0], class);
    assert_eq!(out.logits[0], logits);
    assert!(logits.iter().all(|v| v.is_finite()));

    // Analytic execution books the same placement cycles — the twin
    // changed *what runs*, never *what is charged*.
    let (analytic_fleet, oc_analytic) = mk(ExecutionMode::Analytic);
    assert_eq!(oc_analytic.reload_cycles, oc.reload_cycles);
    let analytic_snap = analytic_fleet.snapshot();
    assert_eq!(analytic_snap.reload_cycles, na + nb + nc);
    assert!(analytic_snap.twin_stats.is_empty(), "no twin pool when analytic");
}

#[test]
fn defragged_pool_beats_first_fit_on_churn() {
    // The PR-4 acceptance scenario: register/retire churn on a 2-macro
    // co-resident twin pool, then a steady request mix. Under first-fit
    // the late arrival splinters across the freed holes; `--fit best` +
    // `--defrag` keeps every tenant contiguous (one threshold-triggered
    // compaction). The defragged pool must serve the same mix with fewer
    // mean spans per resident tenant and fewer total twin cycles
    // (load + migration + executed passes), with the analytic and twin
    // migration charges equal by construction.
    let spec_ = spec();
    let churn = |fit: FitPolicyKind, defrag_threshold: f64| -> (Fleet, FleetSnapshot) {
        let fleet_cfg = FleetConfig {
            num_macros: 2,
            coresident: true,
            execution: ExecutionMode::Twin,
            fit,
            defrag_threshold,
            ..cfg(EvictionPolicy::Lru)
        };
        let mut fleet = Fleet::new(&fleet_cfg, &spec_);
        let batch: Vec<Vec<f32>> = (0..4).map(img).collect();
        for (name, s) in [("a", 0.04), ("b", 0.03), ("c", 0.05), ("d", 0.04)] {
            fleet.register(name, by_name("vgg9").unwrap().scaled(s), false).unwrap();
            fleet.serve_batch(name, &batch).unwrap();
        }
        fleet.retire("b").unwrap();
        fleet.retire("d").unwrap();
        fleet.register("e", by_name("vgg9").unwrap().scaled(0.05), false).unwrap();
        for _ in 0..16 {
            for m in ["a", "c", "e"] {
                fleet.serve_batch(m, &batch).unwrap();
            }
        }
        let snap = fleet.snapshot();
        (fleet, snap)
    };
    let twin_total = |s: &FleetSnapshot| MacroStats::aggregate(s.twin_stats.iter()).busy_cycles();

    let (_, ff) = churn(FitPolicyKind::FirstFit, 0.0);
    let (mut dg_fleet, dg) = churn(FitPolicyKind::BestFit, 0.3);

    // Fewer spans per resident tenant: first-fit splinters c and e into
    // two spans each (5 spans over 3 tenants); the defragged pool keeps
    // every placement contiguous.
    let ff_frag = ff.fragmentation();
    let dg_frag = dg.fragmentation();
    assert_eq!(ff_frag.resident_spans, 5, "first-fit fragments c and e");
    assert!((ff_frag.mean_spans_per_tenant() - 5.0 / 3.0).abs() < 1e-12);
    assert!((dg_frag.mean_spans_per_tenant() - 1.0).abs() < 1e-12);
    assert!(dg_frag.mean_spans_per_tenant() < ff_frag.mean_spans_per_tenant());

    // One compaction ran, migrating exactly c's footprint (139 columns),
    // and the migration charge is identical in all four ledgers.
    let nc = dg_fleet.registry().get("c").unwrap().bls_needed() as u64;
    assert_eq!(dg.compactions, 1);
    assert_eq!(dg.migration_cycles, nc);
    assert_eq!(dg.macro_migration_cycles(), nc);
    assert_eq!(dg.tenant_migration_cycles(), nc);
    assert_eq!(dg.twin_migration_cycles(), nc, "twin charge equal by construction");
    assert_eq!(ff.migration_cycles, 0);
    assert_eq!(ff.twin_migration_cycles(), 0);

    // Hot-swap traffic is identical (same tenants, same footprints) —
    // the twin-cycle win comes from fewer span writes and fewer passes.
    assert_eq!(ff.reload_cycles, dg.reload_cycles);
    assert!(
        ff.aggregate().reloads > dg.aggregate().reloads,
        "fragmented placements cost extra load events"
    );
    assert!(
        twin_total(&dg) < twin_total(&ff),
        "defrag must win on total twin cycles ({} vs {})",
        twin_total(&dg),
        twin_total(&ff)
    );

    // Load books balance in both arms, migration included.
    for snap in [&ff, &dg] {
        assert_eq!(snap.twin_load_cycles(), snap.reload_cycles);
        assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
        assert_eq!(snap.reload_cycles, snap.tenant_load_cycles());
    }

    // The compacted placements still hold the right weights: readback
    // every resident tenant's columns across the twin pool.
    for name in ["a", "c", "e"] {
        let placed = dg_fleet.placed_mapping(name).unwrap().clone();
        assert_eq!(placed.spans.len(), 1, "{name} is contiguous after defrag");
        let weights = dg_fleet.registry().get(name).unwrap().weights.clone().unwrap();
        for (bl, col) in weights.columns.iter().enumerate() {
            let (mac, local) = placed.locate(bl);
            assert_eq!(&dg_fleet.twin_macros()[mac].read_column(local), col, "{name}:{bl}");
        }
    }
    // And inference over the compacted layout is reachable + finite.
    let (class, logits) = dg_fleet.infer_twin("c", &img(1)).unwrap();
    assert!(class < 10);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn twin_fleet_server_roundtrip_keeps_books_balanced() {
    // The threaded dispatcher path with twin execution: tagged submits,
    // per-model batching, hot-swaps materializing onto the twin — and the
    // final snapshot's twin/ledger agreement survives the whole run.
    let spec_ = spec();
    let cfg = FleetConfig {
        num_macros: 2,
        max_batch: 4,
        batch_timeout_us: 300,
        coresident: true,
        execution: ExecutionMode::Twin,
        ..FleetConfig::default()
    };
    let h = FleetServer::start(&cfg, &spec_);
    h.register("a", by_name("vgg9").unwrap().scaled(0.04), false).unwrap();
    h.register("b", by_name("vgg9").unwrap().scaled(0.03), false).unwrap();
    let total = 24usize;
    let mut tickets = Vec::with_capacity(total);
    for k in 0..total {
        let model = ["a", "b"][k % 2];
        tickets.push(h.submit(model, img(k)).unwrap());
    }
    for t in tickets {
        let r = t.wait().unwrap();
        assert!(r.class < 10);
        assert_eq!(r.logits.len(), 10);
    }
    let (m, snap) = h.shutdown();
    assert_eq!(m.completed, total as u64);
    assert_eq!(snap.execution, ExecutionMode::Twin);
    assert_eq!(snap.twin_load_cycles(), snap.reload_cycles);
    assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
    assert_eq!(snap.reload_cycles, (108 + 82) as u64, "one partial swap each");
    // The twin really computed: executed passes and conversions are on
    // the books (the analytic per-macro ledger never sees pass counts).
    assert!(snap.twin_stats.iter().map(|s| s.conversions).sum::<u64>() > 0);
}

#[test]
fn deterministic_core_hot_swap_sequence() {
    // a, b resident together fill the pool; c forces an eviction; re-serving
    // the victim forces another hot-swap. Exact cycle accounting throughout.
    let mut fleet = Fleet::new(&cfg(EvictionPolicy::Lru), &spec());
    for (i, m) in ["a", "b", "c"].iter().enumerate() {
        fleet.register(m, tenant("vgg9", 20 + i as u64), false).unwrap();
    }
    let load = spec().load_cycles_per_macro as u64;
    let need = |f: &Fleet, m: &str| f.registry().get(m).unwrap().macros_needed() as u64;
    let (na, nb, nc) = (need(&fleet, "a"), need(&fleet, "b"), need(&fleet, "c"));
    assert!(na + nb <= FLEET_MACROS as u64, "a+b co-reside");
    assert!(na + nb + nc > FLEET_MACROS as u64, "c forces eviction");

    let batch = vec![img(0)];
    let o1 = fleet.serve_batch("a", &batch).unwrap();
    assert_eq!(o1.reload_cycles, na * load);
    assert!(o1.evicted.is_empty());

    let o2 = fleet.serve_batch("b", &batch).unwrap();
    assert_eq!(o2.reload_cycles, nb * load);
    assert!(o2.evicted.is_empty());

    // Residency hits are free.
    let o3 = fleet.serve_batch("a", &batch).unwrap();
    assert_eq!(o3.reload_cycles, 0);

    // c evicts the stalest (b, since a was just touched) and reloads.
    let o4 = fleet.serve_batch("c", &batch).unwrap();
    assert_eq!(o4.evicted, vec!["b".to_string()]);
    assert_eq!(o4.reload_cycles, nc * load);

    // b comes back: another hot-swap.
    let o5 = fleet.serve_batch("b", &batch).unwrap();
    assert!(o5.reload_cycles == nb * load && !o5.evicted.is_empty());

    let snap = fleet.snapshot();
    let expected = (na + nb + nc + nb) * load;
    assert_eq!(snap.reload_cycles, expected);
    assert_eq!(snap.macro_load_cycles(), expected);
    assert_eq!(snap.hot_swaps, 4);
    assert!(snap.evictions >= 2);
}

#[test]
fn pinned_tenant_survives_pressure() {
    let mut fleet = Fleet::new(&cfg(EvictionPolicy::Lru), &spec());
    fleet.register("vip", tenant("vgg9", 31), true).unwrap();
    fleet.register("b", tenant("vgg16", 32), false).unwrap();
    fleet.register("c", tenant("resnet18", 33), false).unwrap();
    let batch = vec![img(1)];
    fleet.serve_batch("vip", &batch).unwrap();
    // Churn the other tenants hard; the pinned one must stay resident.
    for _ in 0..6 {
        fleet.serve_batch("b", &batch).unwrap();
        fleet.serve_batch("c", &batch).unwrap();
    }
    assert!(fleet.is_resident("vip"));
    let o = fleet.serve_batch("vip", &batch).unwrap();
    assert_eq!(o.reload_cycles, 0, "pinned tenant never reloads");
    let snap = fleet.snapshot();
    assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
}

#[test]
fn cost_weighted_policy_diverges_from_lru() {
    // A 1-macro tenant (cheap to restore) and a 2-macro tenant (pricier)
    // co-reside with one macro spare; a third 2-macro tenant needs room.
    // Serve order makes "large" the STALE one, so:
    //   * LRU evicts "large" (stalest),
    //   * cost-weighted evicts "small" (cheapest reload) even though it
    //     was used more recently.
    let spec_ = spec();
    let small = {
        let out = morph_flow_synthetic(
            &by_name("vgg9").unwrap(),
            &spec_,
            &MorphConfig {
                target_bl: 256,
                ..MorphConfig::default()
            },
            0.4,
            41,
        );
        out.arch
    };
    let large = tenant("vgg16", 42);
    let small_macros = pack_model(&small, &spec_).num_macros;
    let large_macros = pack_model(&large, &spec_).num_macros;
    assert_eq!(small_macros, 1, "256-BL tenant fits one macro");
    assert!(large_macros > small_macros, "{large_macros} vs {small_macros}");

    for (policy, expect_victim) in [
        (EvictionPolicy::Lru, "large"),
        (EvictionPolicy::CostWeighted, "small"),
    ] {
        let mut fleet = Fleet::new(&cfg(policy), &spec_);
        fleet.register("small", small.clone(), false).unwrap();
        fleet.register("large", large.clone(), false).unwrap();
        fleet.register("third", tenant("resnet18", 43), false).unwrap();
        let batch = vec![img(2)];
        fleet.serve_batch("large", &batch).unwrap();
        fleet.serve_batch("small", &batch).unwrap(); // small is most recent
        let o = fleet.serve_batch("third", &batch).unwrap();
        assert_eq!(
            o.evicted.first().map(|s| s.as_str()),
            Some(expect_victim),
            "{policy:?}: evicted {:?}",
            o.evicted
        );
        let snap = fleet.snapshot();
        assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
    }
}

#[test]
fn retire_frees_capacity_for_new_tenant() {
    let mut fleet = Fleet::new(&cfg(EvictionPolicy::Lru), &spec());
    fleet.register("a", tenant("vgg9", 51), false).unwrap();
    fleet.register("b", tenant("vgg16", 52), false).unwrap();
    let batch = vec![img(3)];
    fleet.serve_batch("a", &batch).unwrap();
    fleet.serve_batch("b", &batch).unwrap();
    fleet.retire("a").unwrap();
    assert!(!fleet.is_resident("a"));
    assert!(fleet.serve_batch("a", &batch).is_err(), "retired = unknown");
    // A new tenant takes the freed macros without evicting b.
    fleet.register("c", tenant("resnet18", 53), false).unwrap();
    let o = fleet.serve_batch("c", &batch).unwrap();
    assert!(o.evicted.is_empty(), "retirement freed room: {:?}", o.evicted);
    assert!(fleet.is_resident("b"));
}

#[test]
fn compressed_fits_where_uncompressed_evicts() {
    // The operational payoff of the paper's Stage-1 compression: under
    // the same alternating request mix against a co-tenant, the morphed
    // VGG9 coexists (one-time swaps only) while the full VGG9 pages
    // through the pool every batch. Strictly fewer reload cycles.
    let spec_ = spec();
    let co_tenant = tenant("vgg16", 61);
    let mix = |fleet: &mut Fleet| {
        let batch: Vec<Vec<f32>> = (0..4).map(img).collect();
        for _ in 0..5 {
            fleet.serve_batch("primary", &batch).unwrap();
            fleet.serve_batch("co", &batch).unwrap();
        }
        fleet.snapshot().reload_cycles
    };

    let mut morphed = Fleet::new(&cfg(EvictionPolicy::Lru), &spec_);
    morphed.register("primary", tenant("vgg9", 62), false).unwrap();
    morphed.register("co", co_tenant.clone(), false).unwrap();
    let morphed_cycles = mix(&mut morphed);

    let mut uncompressed = Fleet::new(&cfg(EvictionPolicy::Lru), &spec_);
    uncompressed.register("primary", by_name("vgg9").unwrap(), false).unwrap();
    uncompressed.register("co", co_tenant, false).unwrap();
    let uncompressed_cycles = mix(&mut uncompressed);

    assert!(
        morphed_cycles < uncompressed_cycles,
        "morphed {morphed_cycles} must beat uncompressed {uncompressed_cycles}"
    );
    // Both books balance.
    assert_eq!(
        morphed.snapshot().reload_cycles,
        morphed.snapshot().macro_load_cycles()
    );
    assert_eq!(
        uncompressed.snapshot().reload_cycles,
        uncompressed.snapshot().macro_load_cycles()
    );
}

#[test]
fn threaded_rate_limited_tenant_rejects_excess_deterministically() {
    // A hard token-bucket cap (burst without refill) is enforced on the
    // dispatcher thread's virtual clock, so it is deterministic even
    // through the threaded path: exactly `burst` requests are ever
    // admitted, the rest reject (tickets error) and charge nothing.
    use cim_adapt::fleet::QosSpec;
    let h = FleetServer::start(&cfg(EvictionPolicy::Lru), &spec());
    h.register_with_qos(
        "capped",
        tenant("vgg9", 31),
        false,
        QosSpec {
            burst: 2,
            ..QosSpec::default()
        },
    )
    .unwrap();
    let mut tickets = Vec::new();
    for k in 0..8 {
        tickets.push(h.submit("capped", img(k)).unwrap());
    }
    let mut served = 0u64;
    let mut refused = 0u64;
    for t in tickets {
        match t.wait_timeout(std::time::Duration::from_secs(10)) {
            Ok(r) => {
                assert!(r.class < 10);
                served += 1;
            }
            Err(_) => refused += 1,
        }
    }
    assert_eq!(served, 2, "hard cap admits exactly the burst");
    assert_eq!(refused, 6);
    let (m, snap) = h.shutdown();
    assert_eq!(m.completed, 2);
    assert_eq!(m.rejected, 6);
    let qos: std::collections::BTreeMap<_, _> = snap.qos_stats.iter().cloned().collect();
    assert_eq!(qos["capped"].admitted, 2);
    assert_eq!(qos["capped"].rejected, 6);
    // Rejected requests charged nothing: the books hold exactly the two
    // served requests' cycles and still conserve.
    assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
    assert_eq!(snap.reload_cycles, snap.tenant_load_cycles());
    assert_eq!(snap.hot_swaps, 1, "one residency-establishing swap");
}

#[test]
fn threaded_priority_tenant_preempts_queued_batch_traffic() {
    // Two tenants' requests parked in queues (long batch timeout): when
    // the queues flush on shutdown, the Interactive tenant's batch
    // dispatches before the Batch tenant's, whatever the submit order.
    use cim_adapt::fleet::{QosClass, QosSpec};
    let h = FleetServer::start(
        &FleetConfig {
            num_macros: FLEET_MACROS,
            max_batch: 64,
            batch_timeout_us: 2_000_000, // park requests until drain
            ..FleetConfig::default()
        },
        &spec(),
    );
    h.register_with_qos(
        "urgent",
        tenant("vgg9", 41),
        false,
        QosSpec {
            class: QosClass::Interactive,
            ..QosSpec::default()
        },
    )
    .unwrap();
    h.register_with_qos(
        "bulk",
        tenant("vgg16", 42),
        false,
        QosSpec {
            class: QosClass::Batch,
            ..QosSpec::default()
        },
    )
    .unwrap();
    let mut tickets = Vec::new();
    for k in 0..4 {
        tickets.push(h.submit("bulk", img(k)).unwrap());
    }
    for k in 4..8 {
        tickets.push(h.submit("urgent", img(k)).unwrap());
    }
    // Shutdown drains the parked queues in QoS order.
    let (m, snap) = h.shutdown();
    assert_eq!(m.completed, 8);
    for t in tickets {
        let r = t.wait_timeout(std::time::Duration::from_secs(10)).unwrap();
        assert!(r.class < 10);
    }
    // The urgent batch went first: bulk's requests waited through
    // urgent's service cycles on the deterministic virtual clock.
    let qos: std::collections::BTreeMap<_, _> = snap.qos_stats.iter().cloned().collect();
    assert!(
        qos["bulk"].queue_delay_cycles > qos["urgent"].queue_delay_cycles,
        "bulk ({}) must wait longer than urgent ({})",
        qos["bulk"].queue_delay_cycles,
        qos["urgent"].queue_delay_cycles
    );
    assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
}

#[test]
fn twin_compute_cycles_equal_analytic_latency_for_every_resident_tenant() {
    // The dataflow engine's headline guarantee: full-spatial twin
    // execution makes twin compute cycles equal the analytic
    // `computing_latency` *by construction* — across a multi-tenant pool
    // with mixed batch sizes — and the activation-buffer ledger is
    // conserved (fleet == Σ per-tenant == twin). Whole-macro placement
    // keeps every placement contiguous; fragmented placements pay one
    // extra twin-observed evaluate per split run and are exercised by
    // `twin_and_analytic_ledgers_agree_on_fragmented_coresident_swap`.
    let spec_ = spec();
    let cfg = FleetConfig {
        execution: ExecutionMode::Twin,
        ..cfg(EvictionPolicy::Lru)
    };
    let mut fleet = Fleet::new(&cfg, &spec_);
    let tenants = [("a", 0.04, 2usize), ("b", 0.03, 1), ("c", 0.05, 3)];
    for (name, s, images) in tenants {
        fleet.register(name, by_name("vgg9").unwrap().scaled(s), false).unwrap();
        let batch: Vec<Vec<f32>> = (0..images).map(img).collect();
        fleet.serve_batch(name, &batch).unwrap();
    }
    let snap = fleet.snapshot();
    let mut expect_compute = 0u64;
    let mut expect_conversions = 0u64;
    let mut expect_buffer = cim_adapt::latency::BufferTraffic::default();
    for (name, _, images) in tenants {
        assert!(fleet.is_resident(name), "{name} stays resident");
        let entry = fleet.registry().get(name).unwrap();
        expect_compute += entry.cost.computing_latency as u64 * images as u64;
        expect_conversions += entry.cost.macs as u64 * images as u64;
        expect_buffer.absorb(entry.buffer_traffic(snap.dataflow).scaled(images as u64));
    }
    let twin = MacroStats::aggregate(snap.twin_stats.iter());
    assert_eq!(twin.compute_cycles, expect_compute, "twin == analytic latency");
    assert_eq!(twin.conversions, expect_conversions, "twin conversions == MACs");
    // The analytic per-macro compute books agree with the twin's.
    assert_eq!(snap.aggregate().compute_cycles, twin.compute_cycles);
    // Buffer-ledger conservation across all three views.
    assert_eq!(snap.buffer_fleet, expect_buffer);
    assert_eq!(snap.buffer_twin, snap.buffer_fleet);
    assert_eq!(snap.tenant_buffer(), snap.buffer_fleet);
}

#[test]
fn oversized_tenant_completes_a_twin_forward_via_paging() {
    // A tenant bigger than the whole pool (3,676 BLs on 4×256 columns)
    // cannot become resident, but within the paging headroom the twin
    // executes it anyway: a weight-stationary load-on-demand schedule
    // streams each phase's columns into a scratch pool, the paging
    // charge lands on `region_reload_cycles` analytically and on the
    // twin mirror, and the forward still satisfies the compute-equality
    // guarantee.
    let spec_ = spec();
    let fleet_cfg = FleetConfig {
        execution: ExecutionMode::Twin,
        ..cfg(EvictionPolicy::Lru)
    };
    let mut fleet = Fleet::new(&fleet_cfg, &spec_);
    fleet.register("big", by_name("vgg9").unwrap().scaled(0.3), false).unwrap();
    let entry_bls = fleet.registry().get("big").unwrap().bls_needed() as u64;
    assert!(
        entry_bls > (FLEET_MACROS * spec_.bitlines) as u64,
        "tenant must exceed the pool ({entry_bls} BLs)"
    );

    let out = fleet.serve_batch("big", &[img(3)]).unwrap();
    assert_eq!(out.classes.len(), 1);
    assert!(out.logits[0].iter().all(|v| v.is_finite()));
    assert!(!fleet.is_resident("big"), "paged tenants never become resident");

    let snap = fleet.snapshot();
    let cost = fleet.registry().get("big").unwrap().cost.clone();
    let twin = MacroStats::aggregate(snap.twin_stats.iter());
    // The twin genuinely executed the forward: every MAC ran (conversions
    // are exact), and compute cycles are at least the analytic latency —
    // segments that straddle a page/slot boundary split into extra
    // evaluate steps, so the paged path can only pay *more* than the
    // resident path's exact-equality bound, never less.
    assert_eq!(twin.conversions, cost.macs as u64);
    assert!(
        twin.compute_cycles >= cost.computing_latency as u64,
        "paged compute {} must cover the analytic latency {}",
        twin.compute_cycles,
        cost.computing_latency
    );
    // Paging charged exactly the footprint, mirrored on the twin ledger.
    assert_eq!(snap.reload_cycles, entry_bls);
    assert_eq!(snap.twin_load_cycles(), snap.reload_cycles);
    assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
    assert_eq!(snap.reload_cycles, snap.tenant_load_cycles());
    // Buffer ledger conserved for the paged path too.
    assert!(snap.buffer_fleet.total() > 0);
    assert_eq!(snap.buffer_twin, snap.buffer_fleet);
    assert_eq!(snap.tenant_buffer(), snap.buffer_fleet);
}

#[test]
fn shared_backbone_fleet_reloads_only_deltas() {
    // One 108-column base plus two fine-tuned heads on a single macro
    // under content-addressed dedup: each head's hot-swap charges
    // exactly its classifier delta on all four ledgers, the whole family
    // co-resides, and eviction pressure can take the heads but never the
    // refcount-pinned base their borrowed spans live in.
    let fcfg = FleetConfig {
        num_macros: 1,
        dedup: true,
        max_batch: 4,
        batch_timeout_us: 300,
        ..FleetConfig::default()
    };
    let mut fleet = Fleet::new(&fcfg, &spec());
    fleet
        .register("base", by_name("vgg9").unwrap().scaled(0.04), false)
        .unwrap();
    fleet.register_derived("head-a", "base", false).unwrap();
    fleet.register_derived("head-b", "base", false).unwrap();
    let total = fleet.registry().get("base").unwrap().bls_needed() as u64;

    let ob = fleet.serve_batch("base", &[img(0)]).unwrap();
    assert_eq!(ob.reload_cycles, total, "the first loader pays in full");
    let oa = fleet.serve_batch("head-a", &[img(1)]).unwrap();
    let da = oa.reload_cycles;
    assert!(da > 0 && da < total, "head-a pays only its delta ({da} of {total})");
    assert!(oa.evicted.is_empty());
    let obh = fleet.serve_batch("head-b", &[img(2)]).unwrap();
    let db = obh.reload_cycles;
    assert!(db > 0 && db < total, "head-b pays only its delta ({db} of {total})");
    assert!(obh.evicted.is_empty(), "the family co-resides on one macro");

    // Hot-swapping between the heads is now free — everything resident.
    assert_eq!(fleet.serve_batch("head-a", &[img(3)]).unwrap().reload_cycles, 0);
    assert_eq!(fleet.serve_batch("head-b", &[img(4)]).unwrap().reload_cycles, 0);

    // Exactly the delta footprint landed, on every view.
    let snap = fleet.snapshot();
    assert_eq!(snap.reload_cycles, total + da + db);
    assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
    assert_eq!(snap.reload_cycles, snap.tenant_load_cycles());
    let stats: std::collections::BTreeMap<_, _> = snap.tenant_stats.iter().cloned().collect();
    assert_eq!(stats["base"].load_cycles, total);
    assert_eq!(stats["head-a"].load_cycles, da);
    assert_eq!(stats["head-b"].load_cycles, db);
    // Both heads borrow their whole backbone; the avoided cycles equal
    // the borrowed widths on the default spec.
    assert_eq!(snap.dedup_shared_bls as u64, 2 * total - da - db);
    assert_eq!(snap.dedup_shared_cycles, 2 * total - da - db);
    assert_eq!(
        snap.dedup_resident_bls(),
        snap.occupied_bls.iter().sum::<usize>(),
        "own spans tile exactly the occupied columns"
    );

    // Pressure: a 139-column tenant forces an LRU sweep. Heads are fair
    // game; the base is pinned by their live references and survives.
    fleet
        .register("pressure", by_name("vgg9").unwrap().scaled(0.05), false)
        .unwrap();
    let op = fleet.serve_batch("pressure", &[img(5)]).unwrap();
    assert!(
        op.evicted.iter().all(|m| m.starts_with("head")),
        "only heads may be evicted, got {:?}",
        op.evicted
    );
    assert!(fleet.is_resident("base"), "the borrowed-from base must survive");

    // The surviving backbone still serves both heads at delta cost:
    // whatever the sweep took, re-serving a head never pays more than
    // its delta — the spans it references were never freed.
    let ra = fleet.serve_batch("head-a", &[img(6)]).unwrap();
    assert!(ra.reload_cycles <= da, "head-a re-pays at most its delta ({})", ra.reload_cycles);
    let rb = fleet.serve_batch("head-b", &[img(7)]).unwrap();
    assert!(rb.reload_cycles <= db, "head-b re-pays at most its delta ({})", rb.reload_cycles);

    // Conservation holds through the churn, and the dedup books balance.
    let fin = fleet.snapshot();
    assert_eq!(fin.reload_cycles, fin.macro_load_cycles());
    assert_eq!(fin.reload_cycles, fin.tenant_load_cycles());
    assert_eq!(
        fin.dedup_resident_bls(),
        fin.occupied_bls.iter().sum::<usize>()
    );
    assert!(fin.dedup_ratio() > 1.0);
}
