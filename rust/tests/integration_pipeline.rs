//! Integration: the whole Stage-1 + cost + mapping stack composed —
//! morph → pack → schedule → simulate, across models and macro specs.

use cim_adapt::arch::{by_name, MODEL_NAMES};
use cim_adapt::cim::{CimMacro, WeightCell};
use cim_adapt::config::{MacroSpec, MorphConfig};
use cim_adapt::coordinator::MacroScheduler;
use cim_adapt::latency::{model_cost, cost::allocated_usage};
use cim_adapt::mapping::{pack_model, OccupancyGrid};
use cim_adapt::morph::flow::morph_flow_synthetic;
use cim_adapt::quant::lsq::LsqTensor;
use cim_adapt::util::prng::Pcg;

#[test]
fn morph_pack_schedule_compose_for_all_models() {
    let spec = MacroSpec::default();
    for model in MODEL_NAMES {
        let arch = by_name(model).unwrap();
        let cfg = MorphConfig {
            target_bl: 1024,
            ..MorphConfig::default()
        };
        let out = morph_flow_synthetic(&arch, &spec, &cfg, 0.4, 5);
        let mapping = pack_model(&out.arch, &spec);
        assert_eq!(mapping.total_bls, out.cost.bls);
        assert!(mapping.num_macros <= 4, "{model}: {}", mapping.num_macros);

        let sched = MacroScheduler::new(&mapping, &out.cost, &spec, 4);
        assert_eq!(sched.plan.reloads_per_inference, 0, "{model} fits in 4 macros");
        assert_eq!(sched.plan.compute_cycles, out.cost.computing_latency as u64);

        // Occupancy grids agree with the analytic usage.
        let grids = OccupancyGrid::from_mapping(&mapping);
        let fill: f64 = grids.iter().map(|g| g.fill()).sum::<f64>() / grids.len() as f64;
        assert!((fill - mapping.occupancy()).abs() < 1e-9);
    }
}

#[test]
fn morphed_model_executes_on_digital_twin() {
    // Morph VGG9 to one macro (256 BLs), quantize random weights with
    // LSQ, load the first layer onto the twin and run an input through —
    // verifying the packer's layout drives the macro correctly.
    let spec = MacroSpec::default();
    let cfg = MorphConfig {
        target_bl: 256,
        ..MorphConfig::default()
    };
    let out = morph_flow_synthetic(&by_name("vgg9").unwrap(), &spec, &cfg, 0.5, 9);
    let mapping = pack_model(&out.arch, &spec);
    assert_eq!(mapping.num_macros, 1);

    let mut rng = Pcg::new(3);
    let mut mac = CimMacro::new(spec, 0.1, 16.0);
    let layer0 = &mapping.layers[0];
    let l0 = &out.arch.layers[0];
    // Random float weights → LSQ 4-bit codes → cells.
    let ws: Vec<f32> = (0..l0.rows() * l0.c_out)
        .map(|_| (rng.next_f32() - 0.5) * 0.4)
        .collect();
    let t = LsqTensor::calibrate(&ws, 4);
    for seg in 0..layer0.segments {
        let cols: Vec<Vec<WeightCell>> = (0..layer0.c_out)
            .map(|f| {
                (0..layer0.rows_per_segment[seg])
                    .map(|r| WeightCell::saturating(t.codes[f * l0.rows() + r], 4))
                    .collect()
            })
            .collect();
        mac.load_columns(layer0.column(seg, 0), &cols);
    }
    let codes: Vec<i32> = (0..l0.rows()).map(|_| rng.gen_range(16) as i32).collect();
    let outv = mac.segmented_matvec(&[codes], layer0.c_out, t.step, false);
    assert_eq!(outv.len(), layer0.c_out);
    assert!(outv.iter().all(|v| v.is_finite()));
    assert_eq!(mac.stats.reloads as usize, layer0.segments);
}

#[test]
fn smaller_macro_specs_still_compose() {
    // A 128×128 macro with 32 ADCs: everything recomputes consistently.
    let spec = MacroSpec {
        wordlines: 128,
        bitlines: 128,
        num_adcs: 32,
        load_cycles_per_macro: 128,
        ..MacroSpec::default()
    };
    let arch = by_name("vgg9").unwrap();
    let cfg = MorphConfig {
        target_bl: 512,
        ..MorphConfig::default()
    };
    let out = morph_flow_synthetic(&arch, &spec, &cfg, 0.4, 7);
    assert!(out.cost.bls <= 512);
    let mapping = pack_model(&out.arch, &spec);
    assert_eq!(mapping.num_macros, out.cost.macros_needed(&spec));
    // 3×3 on 128 WLs: 14 channels per column → ≤ 126/128 rows used.
    let u = allocated_usage(&model_cost(&out.arch, &spec), &spec);
    assert!(u <= 126.0 / 128.0 + 1e-9, "u={u}");
}

#[test]
fn arch_json_roundtrip_through_morph() {
    // The morphed arch must survive the JSON interchange used between the
    // python trainer and the rust coordinator.
    let spec = MacroSpec::default();
    let cfg = MorphConfig {
        target_bl: 2048,
        ..MorphConfig::default()
    };
    let out = morph_flow_synthetic(&by_name("resnet18").unwrap(), &spec, &cfg, 0.4, 13);
    let j = out.arch.to_json();
    let back = cim_adapt::arch::ModelArch::from_json(&j).unwrap();
    assert_eq!(back, out.arch);
    assert_eq!(model_cost(&back, &spec), out.cost);
}
