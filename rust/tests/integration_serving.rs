//! Integration: the edge server under load — correctness, batching,
//! backpressure, concurrency, and failure injection (Sim backend; the
//! PJRT path is covered in integration_runtime.rs).

use std::sync::Arc;
use std::time::Duration;

use cim_adapt::arch::vgg9;
use cim_adapt::config::{MacroSpec, ServeConfig};
use cim_adapt::coordinator::server::{Backend, EdgeServer, ServerHandle};
use cim_adapt::data::SynthCifar;

fn sim_server(cfg: ServeConfig) -> Arc<ServerHandle> {
    EdgeServer::start(
        &cfg,
        Backend::Sim { num_classes: 10 },
        &vgg9().scaled(0.125),
        &MacroSpec::default(),
    )
}

#[test]
fn concurrent_submitters_all_served() {
    let h = sim_server(ServeConfig {
        workers: 3,
        max_batch: 8,
        batch_timeout_us: 500,
        queue_depth: 10_000,
        ..ServeConfig::default()
    });
    let total = 400usize;
    std::thread::scope(|s| {
        let mut joins = Vec::new();
        for t in 0..8usize {
            let h = Arc::clone(&h);
            joins.push(s.spawn(move || {
                let mut ok = 0;
                for k in 0..total / 8 {
                    let img = SynthCifar::sample((t + k) % 10, k as u64);
                    if let Ok(ticket) = h.submit(img.data) {
                        if ticket.wait().is_ok() {
                            ok += 1;
                        }
                    }
                }
                ok
            }));
        }
        let served: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(served, total);
    });
    let m = h.shutdown();
    assert_eq!(m.completed, total as u64);
    assert!(m.batches <= total as u64, "batching must aggregate");
}

#[test]
fn responses_route_to_correct_submitter() {
    // Each request gets its own channel: interleaved submissions must not
    // cross-deliver. Detect by unique ids.
    let h = sim_server(ServeConfig {
        workers: 2,
        max_batch: 4,
        batch_timeout_us: 300,
        ..ServeConfig::default()
    });
    let tickets: Vec<_> = (0..50)
        .map(|k| {
            let img = SynthCifar::sample(k % 10, k as u64);
            h.submit(img.data).unwrap()
        })
        .collect();
    for t in tickets {
        let id = t.id;
        let r = t.wait().unwrap();
        assert_eq!(r.id, id, "response for wrong request");
    }
    h.shutdown();
}

#[test]
fn device_cycles_reflect_macro_plan() {
    // Full-size vgg9 on 2 physical macros pages heavily; the per-request
    // device cycles must include amortized reload cost.
    let spec = MacroSpec::default();
    let big = vgg9(); // needs 151 macros
    let h_small = EdgeServer::start(
        &ServeConfig {
            workers: 1,
            max_batch: 1,
            batch_timeout_us: 1,
            num_macros: 2,
            ..ServeConfig::default()
        },
        Backend::Sim { num_classes: 10 },
        &big,
        &spec,
    );
    let h_big = EdgeServer::start(
        &ServeConfig {
            workers: 1,
            max_batch: 1,
            batch_timeout_us: 1,
            num_macros: 151,
            ..ServeConfig::default()
        },
        Backend::Sim { num_classes: 10 },
        &big,
        &spec,
    );
    let img = SynthCifar::sample(1, 1);
    let r_small = h_small.submit(img.data.clone()).unwrap().wait().unwrap();
    let r_big = h_big.submit(img.data).unwrap().wait().unwrap();
    assert!(
        r_small.device_cycles > r_big.device_cycles,
        "paging device ({}) must cost more than resident ({})",
        r_small.device_cycles,
        r_big.device_cycles
    );
    // Resident device pays compute only: 14 696 cycles for full VGG9.
    assert_eq!(r_big.device_cycles, 14_696);
    h_small.shutdown();
    h_big.shutdown();
}

#[test]
fn shutdown_rejects_new_work() {
    let h = sim_server(ServeConfig::default());
    let img = SynthCifar::sample(0, 0);
    let t = h.submit(img.data.clone()).unwrap();
    t.wait().unwrap();
    h.shutdown();
    assert!(h.submit(img.data).is_err(), "post-shutdown submit must fail");
}

#[test]
fn failure_injection_bad_backend_drops_cleanly() {
    // A PJRT backend pointing at a missing artifact: workers fail to
    // initialize, tickets error out rather than hanging forever.
    let h = EdgeServer::start(
        &ServeConfig {
            workers: 1,
            ..ServeConfig::default()
        },
        Backend::Pjrt {
            artifact_dir: std::path::PathBuf::from("/nonexistent"),
            model: "ghost".into(),
        },
        &vgg9().scaled(0.125),
        &MacroSpec::default(),
    );
    let img = SynthCifar::sample(0, 0);
    // Submit may succeed (queueing) but the wait must not hang.
    if let Ok(t) = h.submit(img.data) {
        let r = t.wait_timeout(Duration::from_secs(5));
        assert!(r.is_err(), "ticket should error when backend is dead");
    }
    h.shutdown();
}

#[test]
fn latency_percentiles_monotone_under_load() {
    let h = sim_server(ServeConfig {
        workers: 2,
        max_batch: 8,
        batch_timeout_us: 1000,
        queue_depth: 10_000,
        ..ServeConfig::default()
    });
    let tickets: Vec<_> = (0..300)
        .map(|k| h.submit(SynthCifar::sample(k % 10, k as u64).data).unwrap())
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let m = h.shutdown();
    let l = &m.latency;
    assert!(l.p50_us <= l.p95_us && l.p95_us <= l.p99_us && l.p99_us <= l.max_us);
    assert_eq!(l.count, 300);
}
