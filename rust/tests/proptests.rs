//! Property-based tests over the coordinator-side invariants: cost model,
//! packing, morphing, quantization, and the CIM digital twin — using the
//! in-crate testkit (shrinking generators; see `util::testkit`).

use cim_adapt::arch::{by_name, vgg9, ConvLayer, LayerKind, ModelArch};
use cim_adapt::cim::{Adc, CimMacro, WeightCell};
use cim_adapt::config::{DataflowKind, ExecutionMode, FleetConfig, MacroSpec, MorphConfig};
use cim_adapt::fleet::{
    column_hash, plan_compaction, Fleet, HashRing, ModelWeights, Placement, QosClass, QosFleet,
    QosSpec, ShardedFleet,
};
use cim_adapt::latency::{
    layer_cost, model_buffer_traffic, model_cost, spans_reload_cycles, BufferTraffic,
};
use cim_adapt::mapping::{pack_model, FitPolicyKind, PlacedMapping, Region, RegionAllocator};
use cim_adapt::morph::expand::search_expansion_ratio;
use cim_adapt::obs::{FleetTrace, LedgerAuditor};
use cim_adapt::quant::lsq::{lsq_quantize, LsqTensor};
use cim_adapt::quant::psum::{quantize_psum, segment_inputs};
use cim_adapt::runtime::ConcurrentFleet;
use cim_adapt::util::json::{Json, JsonError, JsonReader, JsonToken, JsonWriter};
use cim_adapt::util::prng::Pcg;
use cim_adapt::util::testkit::*;

fn layer(c_in: usize, c_out: usize, hw: usize) -> ConvLayer {
    ConvLayer {
        name: "p".into(),
        kind: LayerKind::Standard,
        c_in,
        c_out,
        kernel: 3,
        out_hw: hw,
        input_from: None,
    }
}

#[test]
fn prop_layer_cost_monotone_in_channels() {
    let spec = MacroSpec::default();
    check(
        "cost monotone in c_in/c_out",
        cases(300),
        triples(usizes(1..600), usizes(1..600), usizes(1..33)),
        |&(c_in, c_out, hw)| {
            let base = layer_cost(&layer(c_in, c_out, hw), &spec);
            let more_in = layer_cost(&layer(c_in + 1, c_out, hw), &spec);
            let more_out = layer_cost(&layer(c_in, c_out + 1, hw), &spec);
            more_in.macs >= base.macs
                && more_out.macs >= base.macs
                && more_in.bls >= base.bls
                && more_out.bls >= base.bls
                && more_out.computing_latency >= base.computing_latency
        },
    );
}

#[test]
fn prop_segments_cover_channels_exactly() {
    check(
        "segmentation covers exactly",
        cases(300),
        pairs(usizes(1..2000), usizes(1..257)),
        |&(c_in, cpb)| {
            let segs = segment_inputs(c_in, 3, cpb);
            let covered: usize = segs.iter().map(|(lo, hi)| hi - lo).sum();
            covered == c_in * 9
                && segs.len() == c_in.div_ceil(cpb)
                && segs.windows(2).all(|w| w[0].1 == w[1].0)
        },
    );
}

#[test]
fn prop_packing_bls_equal_cost_bls() {
    let spec = MacroSpec::default();
    check(
        "pack_model total = cost model BLs",
        cases(60),
        pairs(f32s(0.05, 1.2), usizes(0..3)),
        |&(ratio, model_i)| {
            let arch = by_name(["vgg9", "vgg16", "resnet18"][model_i])
                .unwrap()
                .scaled(ratio as f64);
            let mapping = pack_model(&arch, &spec);
            let cost = model_cost(&arch, &spec);
            mapping.total_bls == cost.bls
                && mapping.num_macros == cost.macros_needed(&spec)
        },
    );
}

#[test]
fn prop_expansion_result_always_fits_budget() {
    let spec = MacroSpec::default();
    check(
        "expansion ratio respects budget",
        cases(40),
        pairs(f32s(0.05, 0.6), usizes(256..9000)),
        |&(prune, target)| {
            let pruned = vgg9().scaled(prune as f64);
            let r = search_expansion_ratio(&pruned, &spec, target, 0.001);
            model_cost(&pruned.scaled(r), &spec).bls <= target
        },
    );
}

#[test]
fn prop_lsq_roundtrip_error_bounded_by_half_step() {
    check(
        "LSQ |deq - w| ≤ step/2 inside range",
        cases(500),
        pairs(f32s(-0.6, 0.6), f32s(0.01, 0.3)),
        |&(w, step)| {
            let (_, deq) = lsq_quantize(w, step, 7, 7);
            if w.abs() <= 7.0 * step {
                (deq - w).abs() <= step / 2.0 + 1e-6
            } else {
                // Clipped: error is the distance to the rail.
                (deq.abs() - 7.0 * step).abs() < 1e-5
            }
        },
    );
}

#[test]
fn prop_lsq_tensor_codes_in_range() {
    check(
        "LSQ codes within ±(2^(b-1)-1)",
        cases(200),
        pairs(vecs(f32s(-3.0, 3.0), 1..200), usizes(2..9)),
        |(ws, bits)| {
            let t = LsqTensor::calibrate(ws, *bits as u32);
            let q = (1i32 << (*bits as u32 - 1)) - 1;
            t.codes.iter().all(|c| (-q..=q).contains(c))
        },
    );
}

#[test]
fn prop_psum_quantizer_clips_and_rounds() {
    check(
        "psum codes bounded and error ≤ s/2 inside range",
        cases(500),
        pairs(i64s(-100_000..100_000), usizes(1..64)),
        |&(acc, s)| {
            let s_adc = s as f32;
            let code = quantize_psum(acc, s_adc, 5);
            if code.abs() < 15 {
                (code as f64 * s_adc as f64 - acc as f64).abs() <= s_adc as f64 / 2.0 + 1e-6
            } else {
                code.abs() == 15
            }
        },
    );
}

#[test]
fn prop_adc_monotone_in_input() {
    let adc = Adc::new(5, 8.0);
    check(
        "ADC conversion is monotone",
        cases(400),
        pairs(i64s(-3000..3000), usizes(1..200)),
        |&(a, delta)| adc.convert(a + delta as i64) >= adc.convert(a),
    );
}

#[test]
fn prop_macro_matvec_linear_in_repeated_segments() {
    // Loading the same weights in two segments and driving the same codes
    // doubles the (unclipped) output — adder-tree linearity.
    let spec = MacroSpec::default();
    check(
        "adder tree accumulates linearly",
        cases(100),
        pairs(usizes(1..8), usizes(0..1000)),
        |&(w_mag, seed)| {
            let mut rng = Pcg::new(seed as u64);
            let w = w_mag as i32;
            // Keep |psum| ≤ 15·s_adc so nothing clips: codes ≤ 2, rows 4,
            // |w| ≤ 7 → |analog| ≤ 56; s_adc = 4 → |scaled| ≤ 14.
            let mut mac = CimMacro::new(spec, 1.0, 4.0);
            let col: Vec<WeightCell> = (0..4).map(|_| WeightCell::saturating(w, 4)).collect();
            mac.load_columns(0, &[col.clone()]);
            mac.load_columns(1, &[col]);
            let codes: Vec<i32> = (0..4).map(|_| rng.gen_range(3) as i32).collect();
            let one = mac.segmented_matvec(&[codes.clone()], 1, 1.0, false)[0];
            let two_segs = {
                // segment-major: segment 1 occupies column index 1.
                mac.segmented_matvec(&[codes.clone(), codes.clone()], 1, 1.0, false)[0]
            };
            (two_segs - 2.0 * one).abs() < 1e-4
        },
    );
}

#[test]
fn prop_scaled_arch_valid_and_monotone() {
    check(
        "arch scaling keeps invariants",
        cases(150),
        pairs(f32s(0.05, 3.0), usizes(0..3)),
        |&(ratio, model_i)| {
            let base: ModelArch = by_name(["vgg9", "vgg16", "resnet18"][model_i]).unwrap();
            let s = base.scaled(ratio as f64);
            s.validate().is_ok()
                && (ratio <= 1.0 || s.params() >= base.params())
                && (ratio >= 1.0 || s.params() <= base.params())
        },
    );
}

// ---- util::json: parse ∘ stringify = id over generated values --------------

/// Generator for arbitrary JSON values (depth-bounded).
struct JsonGen {
    depth: usize,
}

fn json_values(depth: usize) -> JsonGen {
    JsonGen { depth }
}

fn gen_string(rng: &mut Pcg) -> String {
    // Exercise escapes, control chars, and multibyte UTF-8.
    const POOL: &[char] = &[
        'a', 'Z', '7', ' ', '_', '"', '\\', '/', '\n', '\t', '\r', '\u{7}', 'π', '€', '日',
    ];
    let len = rng.gen_range(9);
    (0..len).map(|_| POOL[rng.gen_range(POOL.len())]).collect()
}

fn gen_json(rng: &mut Pcg, depth: usize) -> Json {
    let kinds = if depth == 0 { 4 } else { 6 };
    match rng.gen_range(kinds) {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => {
            if rng.chance(0.5) {
                // Integer-valued (the writer's i64 fast path).
                Json::Num(rng.gen_range(2_000_001) as f64 - 1_000_000.0)
            } else {
                // Fractional (the writer's shortest-roundtrip path).
                Json::Num((rng.next_f64() - 0.5) * 1e6)
            }
        }
        3 => Json::Str(gen_string(rng)),
        4 => Json::Arr((0..rng.gen_range(5)).map(|_| gen_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.gen_range(5))
                .map(|_| (gen_string(rng), gen_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

impl Gen for JsonGen {
    type Value = Json;
    fn gen(&self, rng: &mut Pcg) -> Json {
        gen_json(rng, self.depth)
    }
}

#[test]
fn prop_json_roundtrip_compact() {
    check("parse ∘ dump = id", cases(400), json_values(3), |v| {
        Json::parse(&v.dump()).map(|back| back == *v).unwrap_or(false)
    });
}

#[test]
fn prop_json_roundtrip_pretty() {
    check("parse ∘ pretty = id", cases(400), json_values(3), |v| {
        Json::parse(&v.pretty()).map(|back| back == *v).unwrap_or(false)
    });
}

/// Rebuild a [`Json`] tree by driving the streaming reader — the
/// test-side inverse of [`JsonWriter`], used to cross-check the
/// streaming pair against the tree parser.
fn reader_rebuild(bytes: &[u8]) -> Result<Json, JsonError> {
    let mut r = JsonReader::new(bytes);
    let mut out: Option<Json> = None;
    let mut stack: Vec<(Json, Option<String>)> = Vec::new();
    let mut pending_key: Option<String> = None;
    loop {
        let tok = match r.next()? {
            Some(t) => t,
            None => break,
        };
        let done: Option<Json> = match tok {
            JsonToken::ObjBegin => {
                stack.push((Json::obj(), pending_key.take()));
                None
            }
            JsonToken::ArrBegin => {
                stack.push((Json::Arr(Vec::new()), pending_key.take()));
                None
            }
            JsonToken::ObjEnd | JsonToken::ArrEnd => {
                let (v, k) = stack.pop().unwrap();
                pending_key = k;
                Some(v)
            }
            JsonToken::Key(k) => {
                pending_key = Some(k.to_string());
                None
            }
            JsonToken::Null => Some(Json::Null),
            JsonToken::Bool(b) => Some(Json::Bool(b)),
            JsonToken::Num(n) => Some(Json::Num(n)),
            JsonToken::Str(s) => Some(Json::Str(s.to_string())),
        };
        if let Some(v) = done {
            match stack.last_mut() {
                None => out = Some(v),
                Some((Json::Arr(items), _)) => items.push(v),
                Some((Json::Obj(m), _)) => {
                    m.insert(pending_key.take().expect("object value needs key"), v);
                }
                _ => unreachable!(),
            }
        }
    }
    Ok(out.expect("document had a value"))
}

#[test]
fn prop_json_streaming_writer_reader_roundtrip() {
    // Arbitrary trees through the streaming pair: JsonWriter's bytes are
    // byte-for-byte Json::dump, and driving JsonReader over them rebuilds
    // an equal tree — writer ∘ reader = id, with the tree API as oracle.
    check(
        "stream-write ∘ stream-read = id, bytes == dump",
        cases(400),
        json_values(3),
        |v| {
            let mut w = JsonWriter::new();
            w.value(v);
            if w.as_bytes() != v.dump().as_bytes() {
                return false;
            }
            reader_rebuild(w.as_bytes()).map(|back| back == *v).unwrap_or(false)
        },
    );
}

#[test]
fn prop_json_streaming_reader_agrees_with_tree_parser() {
    // For arbitrary inputs — valid docs, corrupted docs, truncations —
    // the streaming reader and the tree parser return the SAME result:
    // equal values on success, equal error (position AND message) on
    // failure. Both front-ends drive one scanner, and this pins it.
    check(
        "streaming reader ≡ tree parser on corrupted inputs",
        cases(300),
        triples(json_values(2), usizes(0..4), usizes(0..64)),
        |(v, mode, at)| {
            let (mode, at) = (*mode, *at);
            let mut s = v.dump().into_bytes();
            match mode {
                0 => {}                                      // pristine
                1 => s.truncate(at.min(s.len())),            // truncated
                2 => {
                    if !s.is_empty() {
                        s[at % s.len()] = b';';              // corrupted byte
                    }
                }
                _ => s.insert(at.min(s.len()), b'@'),        // inserted garbage
            }
            let tree = match std::str::from_utf8(&s) {
                Ok(text) => Json::parse(text),
                Err(_) => return true, // corrupted multibyte: tree API needs str
            };
            let streamed = reader_rebuild(&s);
            match (tree, streamed) {
                (Ok(a), Ok(b)) => a == b,
                (Err(a), Err(b)) => a == b,
                _ => false,
            }
        },
    );
}

#[test]
fn prop_json_trailing_garbage_error_points_at_it() {
    check(
        "trailing-garbage error position = start of garbage",
        cases(200),
        json_values(2),
        |v| {
            let dumped = v.dump();
            let broken = format!("{dumped}@@");
            match Json::parse(&broken) {
                Err(e) => e.pos == dumped.len(),
                Ok(_) => false,
            }
        },
    );
}

// ---- placed mappings: multi-span packing preserves the weights -------------

#[test]
fn prop_placed_mapping_preserves_every_weight_cell() {
    // Over random fragmentations of a pool (blockers allocated, alternate
    // ones freed, tenant allocated across the holes):
    //   1. loading the placed spans into real macros and reading back per
    //      logical column reproduces the packed weight columns exactly —
    //      and matches a contiguous base-0 packing's cells,
    //   2. per-span footprints sum to the model's used cells,
    //   3. the twin's charged load cycles equal `spans_reload_cycles`.
    let spec = MacroSpec::default();
    check(
        "placed spans preserve cells + footprints + load cycles",
        cases(20),
        pairs(f32s(0.03, 0.08), vecs(usizes(1..120), 0..6)),
        |(scale, blockers)| {
            let arch = vgg9().scaled(*scale as f64);
            let mapping = pack_model(&arch, &spec);
            let total = mapping.total_bls;
            let num_macros = total / spec.bitlines + 2;
            // Fragment: allocate blockers, free every other one.
            let mut alloc = RegionAllocator::new(num_macros, spec.bitlines);
            let held: Vec<_> = blockers.iter().filter_map(|&b| alloc.alloc(b)).collect();
            for (i, r) in held.iter().enumerate() {
                if i % 2 == 1 {
                    alloc.release(r);
                }
            }
            let Some(spans) = alloc.alloc(total) else {
                return true; // blockers left too little room — vacuous
            };
            let span_widths: Vec<usize> = spans.iter().map(|r| r.bl_count).collect();
            let weights = ModelWeights::synthesize("prop-tenant", &arch, &mapping, &spec);
            let placed = match PlacedMapping::new(mapping.clone(), spans) {
                Ok(p) => p,
                Err(_) => return false,
            };

            // Materialize the placed spans and a contiguous reference.
            let mut pool: Vec<CimMacro> =
                (0..num_macros).map(|_| CimMacro::new(spec, 1.0, 16.0)).collect();
            for (span, range) in placed.span_ranges() {
                pool[span.macro_id].load_columns(span.bl_start, &weights.columns[range]);
            }
            let contiguous = PlacedMapping::from_contiguous(&arch, &spec, 0).unwrap();
            let mut ref_pool: Vec<CimMacro> =
                (0..num_macros).map(|_| CimMacro::new(spec, 1.0, 16.0)).collect();
            for (span, range) in contiguous.span_ranges() {
                ref_pool[span.macro_id].load_columns(span.bl_start, &weights.columns[range]);
            }

            // (1) readback per logical column, against the cache and the
            // contiguous packing.
            let cells_preserved = (0..total).all(|bl| {
                let (m, local) = placed.locate(bl);
                let (rm, rlocal) = contiguous.locate(bl);
                let col = pool[m].read_column(local);
                col == weights.columns[bl] && col == ref_pool[rm].read_column(rlocal)
            });
            // (2) span footprints partition the used cells.
            let fp = placed.span_footprints();
            let footprints_sum = fp.len() == placed.spans.len()
                && fp.iter().sum::<usize>() == placed.used_cells()
                && placed
                    .macro_footprint()
                    .iter()
                    .map(|&(_, c)| c)
                    .sum::<usize>()
                    == placed.used_cells();
            // (3) twin charge == the analytic per-span figure.
            let charged: u64 = pool.iter().map(|m| m.stats.load_cycles).sum();
            let charge_agrees = charged == spans_reload_cycles(span_widths, &spec);
            cells_preserved && footprints_sum && charge_agrees
        },
    );
}

#[test]
fn prop_twin_fleet_load_books_always_balance() {
    // Any co-resident request sequence on a twin-executing fleet: the
    // twin pool's charged load cycles equal the analytic ledger, which
    // equals the per-macro and per-tenant sums (resident materializations
    // and mirrored paging charges both included).
    let spec = MacroSpec::default();
    check(
        "twin fleet: twin loads == analytic reload ledger",
        cases(15),
        pairs(vecs(usizes(0..3), 1..14), usizes(1..4)),
        |(seq, num_macros)| {
            let cfg = FleetConfig {
                num_macros: *num_macros,
                coresident: true,
                execution: ExecutionMode::Twin,
                ..FleetConfig::default()
            };
            let mut fleet = Fleet::new(&cfg, &spec);
            for (i, scale) in [0.04, 0.06, 0.1].iter().enumerate() {
                fleet
                    .register(&format!("m{i}"), vgg9().scaled(*scale), false)
                    .unwrap();
            }
            let img = vec![0.5f32; 64];
            for &m in seq {
                let _ = fleet.serve_batch(&format!("m{m}"), &[img.clone()]);
            }
            let snap = fleet.snapshot();
            snap.twin_load_cycles() == snap.reload_cycles
                && snap.reload_cycles == snap.macro_load_cycles()
                && snap.reload_cycles == snap.tenant_load_cycles()
                && snap.twin_migration_cycles() == snap.migration_cycles
        },
    );
}

#[test]
fn prop_compaction_preserves_cells_and_ledgers() {
    // Arbitrary serve / retire+re-register churn on a twin-executing
    // co-resident fleet, then an online compaction: every resident
    // tenant's twin readback still equals its registry weight columns
    // (pre-move source of truth), placements stay pairwise disjoint and
    // consistent with the allocator, and the 4-ledger conservation holds
    // for BOTH charge classes (load and migration) with the twin equal
    // to the analytic ledger by construction.
    let spec = MacroSpec::default();
    let scales = [0.04f64, 0.03, 0.05];
    check(
        "compact: readback + disjoint + 4-ledger conservation",
        cases(12),
        pairs(vecs(usizes(0..6), 1..16), usizes(1..4)),
        |(ops, num_macros)| {
            let cfg = FleetConfig {
                num_macros: *num_macros,
                coresident: true,
                execution: ExecutionMode::Twin,
                fit: FitPolicyKind::BestFit,
                ..FleetConfig::default()
            };
            let mut fleet = Fleet::new(&cfg, &spec);
            for (i, s) in scales.iter().enumerate() {
                fleet
                    .register(&format!("m{i}"), vgg9().scaled(*s), false)
                    .unwrap();
            }
            let img = vec![0.5f32; 64];
            for &op in ops {
                let name = format!("m{}", op % 3);
                if op < 3 {
                    let _ = fleet.serve_batch(&name, &[img.clone()]);
                } else {
                    // The churn that fragments: vacate and come back.
                    fleet.retire(&name).unwrap();
                    fleet
                        .register(&name, vgg9().scaled(scales[op % 3]), false)
                        .unwrap();
                }
            }
            if fleet.compact().is_err() {
                return false;
            }
            let snap = fleet.snapshot();
            // Disjoint placements consistent with the allocator view.
            let regions: Vec<Region> = snap
                .resident
                .iter()
                .flat_map(|p| p.regions.clone())
                .collect();
            let disjoint = regions
                .iter()
                .enumerate()
                .all(|(i, a)| regions[i + 1..].iter().all(|b| !a.overlaps(b)));
            let mut per_macro = vec![0usize; *num_macros];
            for r in &regions {
                per_macro[r.macro_id] += r.bl_count;
            }
            let occupancy_ok = per_macro == snap.occupied_bls;
            // Readback: every materialized tenant holds its cached columns.
            let cells_ok = snap.resident.iter().all(|p| {
                let Some(placed) = fleet.placed_mapping(&p.model) else {
                    return false;
                };
                let entry = fleet.registry().get(&p.model).unwrap();
                let weights = entry.weights.as_ref().unwrap();
                weights.columns.iter().enumerate().all(|(bl, col)| {
                    let (mac, local) = placed.locate(bl);
                    &fleet.twin_macros()[mac].read_column(local) == col
                })
            });
            // Conservation, migration charges included.
            let books_ok = snap.twin_load_cycles() == snap.reload_cycles
                && snap.reload_cycles == snap.macro_load_cycles()
                && snap.reload_cycles == snap.tenant_load_cycles()
                && snap.twin_migration_cycles() == snap.migration_cycles
                && snap.migration_cycles == snap.macro_migration_cycles()
                && snap.migration_cycles == snap.tenant_migration_cycles();
            disjoint && occupancy_ok && cells_ok && books_ok
        },
    );
}

#[test]
fn prop_compaction_plans_are_sound() {
    // Over random allocate/free churn: the planner's targets stay inside
    // the pool, pairwise disjoint (relocated layouts + untouched
    // placements together), width-preserving per tenant and per move,
    // priced exactly `spans_reload_cycles(move widths)` — and iterating
    // plan→execute under the improvement gate reaches a fixpoint within
    // a few passes, with the `(spans, -largest_free_run)` measure
    // strictly decreasing at every executed step (termination).
    let spec = MacroSpec::default();
    let apply = |layout: &[Placement], plan: &cim_adapt::fleet::CompactionPlan| {
        layout
            .iter()
            .map(|p| Placement {
                model: p.model.clone(),
                regions: plan
                    .relocated
                    .iter()
                    .find(|(n, _)| n == &p.model)
                    .map(|(_, l)| l.clone())
                    .unwrap_or_else(|| p.regions.clone()),
            })
            .collect::<Vec<Placement>>()
    };
    let largest_free = |layout: &[Placement], num_macros: usize| {
        let mut check = RegionAllocator::new(num_macros, spec.bitlines);
        let flat: Vec<Region> = layout.iter().flat_map(|p| p.regions.clone()).collect();
        if !check.reserve(&flat) {
            return None; // out of bounds / overlapping: soundness failure
        }
        Some(check.largest_free_run())
    };
    check(
        "compaction plans: sound, priced, terminating",
        cases(60),
        pairs(vecs(usizes(1..200), 1..10), usizes(1..4)),
        |(sizes, num_macros)| {
            let mut alloc = RegionAllocator::new(*num_macros, spec.bitlines);
            let mut all = Vec::new();
            for (i, &w) in sizes.iter().enumerate() {
                if let Some(regions) = alloc.alloc(w) {
                    all.push(Placement {
                        model: format!("t{i}"),
                        regions,
                    });
                }
            }
            // Free every other tenant to splinter the pool.
            let mut kept = Vec::new();
            for (i, p) in all.into_iter().enumerate() {
                if i % 2 == 0 {
                    kept.push(p);
                } else {
                    alloc.release(&p.regions);
                }
            }
            let mut layout = kept;
            let mut prev_measure: Option<(usize, i64)> = None;
            for _round in 0..8 {
                let Some(current_largest) = largest_free(&layout, *num_macros) else {
                    return false;
                };
                let plan = plan_compaction(&layout, *num_macros, spec.bitlines, &spec);
                let widths_ok = plan
                    .moves
                    .iter()
                    .all(|m| m.from.bl_count == m.to.bl_count)
                    && plan.relocated.iter().all(|(n, l)| {
                        let old: usize = layout
                            .iter()
                            .find(|p| &p.model == n)
                            .map(|p| p.bls())
                            .unwrap_or(usize::MAX);
                        l.iter().map(|r| r.bl_count).sum::<usize>() == old
                    });
                let priced_ok = plan.migration_cycles
                    == spans_reload_cycles(plan.moves.iter().map(|m| m.to.bl_count), &spec);
                if !(widths_ok && priced_ok) {
                    return false;
                }
                if !plan.improves(current_largest) {
                    // Fixpoint reached; the final layout must be sound.
                    return largest_free(&layout, *num_macros).is_some();
                }
                let measure = (plan.spans_after, -(plan.largest_free_run_after as i64));
                if let Some(prev) = prev_measure {
                    if measure >= prev {
                        return false; // measure must strictly decrease
                    }
                }
                prev_measure = Some(measure);
                layout = apply(&layout, &plan);
            }
            false // never reached a fixpoint within the bound
        },
    );
}

// ---- fleet: reload accounting conservation ---------------------------------

#[test]
fn prop_coresident_regions_disjoint_and_books_balance() {
    // Under random co-resident request sequences over fractional-macro
    // tenants (resident and paging paths both exercised):
    //   1. resident regions never overlap,
    //   2. per-macro occupied columns equal the sum of resident tenants'
    //      region columns in that macro (× wordlines: occupied cells),
    //   3. fleet-level reload cycles equal the per-macro MacroStats sum
    //      AND the per-tenant attribution sum (extends the PR-1
    //      conservation invariant to shared macros).
    let spec = MacroSpec::default();
    check(
        "co-resident placements: disjoint regions + 3-ledger conservation",
        cases(25),
        pairs(vecs(usizes(0..3), 1..20), usizes(1..5)),
        |(seq, num_macros)| {
            let cfg = FleetConfig {
                num_macros: *num_macros,
                coresident: true,
                ..FleetConfig::default()
            };
            let mut fleet = Fleet::new(&cfg, &spec);
            // 0.04 → ~108 BLs, 0.06 → ~1–2 macros, 0.1 → ~2 macros: on
            // small pools the larger tenants take the paging path.
            for (i, scale) in [0.04, 0.06, 0.1].iter().enumerate() {
                fleet
                    .register(&format!("m{i}"), vgg9().scaled(*scale), false)
                    .unwrap();
            }
            let img = vec![0.5f32; 64];
            for &m in seq {
                let _ = fleet.serve_batch(&format!("m{m}"), &[img.clone()]);
            }
            let snap = fleet.snapshot();
            // (1) pairwise-disjoint regions across all placements.
            let regions: Vec<_> = snap
                .resident
                .iter()
                .flat_map(|p| p.regions.clone())
                .collect();
            let disjoint = regions
                .iter()
                .enumerate()
                .all(|(i, a)| regions[i + 1..].iter().all(|b| !a.overlaps(b)));
            // (2) allocator occupancy == per-macro sum of resident regions.
            let mut per_macro = vec![0usize; *num_macros];
            for r in &regions {
                per_macro[r.macro_id] += r.bl_count;
            }
            let occupancy_consistent = per_macro == snap.occupied_bls;
            // (3) three-ledger conservation.
            let conserved = snap.reload_cycles == snap.macro_load_cycles()
                && snap.reload_cycles == snap.tenant_load_cycles()
                && snap.tenant_aggregate() == snap.aggregate();
            disjoint && occupancy_consistent && conserved
        },
    );
}

#[test]
fn prop_fleet_reload_accounting_conserves() {
    // Any request sequence over tenants of mixed footprint (resident and
    // paging paths both exercised): fleet-level reload cycles always
    // equal the per-macro load-cycle sum.
    let spec = MacroSpec::default();
    check(
        "fleet reload cycles == Σ per-macro load cycles",
        cases(25),
        pairs(vecs(usizes(0..3), 1..20), usizes(2..7)),
        |(seq, num_macros)| {
            let cfg = FleetConfig {
                num_macros: *num_macros,
                ..FleetConfig::default()
            };
            let mut fleet = Fleet::new(&cfg, &spec);
            // 0.1 → ~2 macros, 0.16 → ~5, 0.25 → ~11: on small pools the
            // larger tenants take the paging path.
            for (i, scale) in [0.1, 0.16, 0.25].iter().enumerate() {
                fleet
                    .register(&format!("m{i}"), vgg9().scaled(*scale), false)
                    .unwrap();
            }
            let img = vec![0.5f32; 64];
            for &m in seq {
                let _ = fleet.serve_batch(&format!("m{m}"), &[img.clone()]);
            }
            let snap = fleet.snapshot();
            snap.reload_cycles == snap.macro_load_cycles()
        },
    );
}

#[test]
fn prop_morph_flow_fits_any_budget() {
    let spec = MacroSpec::default();
    check(
        "morph flow result ≤ budget",
        cases(25),
        triples(usizes(256..10_000), usizes(0..1000), f32s(0.1, 0.8)),
        |&(target, seed, sparsity)| {
            let cfg = MorphConfig {
                target_bl: target,
                rounds: 2,
                ..MorphConfig::default()
            };
            let out = cim_adapt::morph::flow::morph_flow_synthetic(
                &vgg9(),
                &spec,
                &cfg,
                sparsity as f64,
                seed as u64,
            );
            out.cost.bls <= target && out.arch.validate().is_ok()
        },
    );
}

#[test]
fn prop_qos_no_admitted_request_starves() {
    // Any mixed-priority submit script over an overloaded pool, with or
    // without an admission budget and aging: draining serves EVERY
    // admitted request (the defer bound + forced progress guarantee it),
    // and the ledgers still conserve.
    let spec = MacroSpec::default();
    check(
        "every admitted request is eventually served",
        cases(25),
        triples(vecs(usizes(0..3), 1..24), usizes(0..3), usizes(0..2000)),
        |(seq, budget_sel, aging)| {
            let mut cfg = FleetConfig {
                num_macros: 1,
                coresident: true,
                qos_aging_cycles: *aging as u64,
                admit_budget_cycles: [0u64, 600, 5000][*budget_sel],
                ..FleetConfig::default()
            };
            cfg.qos.insert(
                "m0".into(),
                QosSpec {
                    class: QosClass::Pinned,
                    ..QosSpec::default()
                },
            );
            cfg.qos.insert(
                "m2".into(),
                QosSpec {
                    class: QosClass::Batch,
                    ..QosSpec::default()
                },
            );
            let mut fleet = QosFleet::new(&cfg, &spec);
            for (i, s) in [0.04, 0.03, 0.05].iter().enumerate() {
                fleet
                    .register(&format!("m{i}"), vgg9().scaled(*s), false)
                    .unwrap();
            }
            let img = vec![0.5f32; 64];
            for &m in seq {
                let _ = fleet.submit(&format!("m{m}"), vec![img.clone()]).unwrap();
            }
            let outcomes = fleet.drain().unwrap();
            let snap = fleet.snapshot();
            let totals = snap.qos_totals();
            let served: u64 = outcomes.iter().map(|o| o.batch as u64).sum();
            served == totals.admitted
                && fleet.pending_batches() == 0
                && totals.admitted + totals.rejected == seq.len() as u64
                && snap.reload_cycles == snap.macro_load_cycles()
                && snap.reload_cycles == snap.tenant_load_cycles()
        },
    );
}

#[test]
fn prop_qos_rejected_requests_charge_nothing() {
    // Any interleaved submit/dispatch script through a rate-limited twin
    // fleet: replaying only the admitted sub-script reproduces every
    // ledger (fleet, per-macro, per-tenant, twin) bit for bit — rejected
    // requests left zero trace anywhere, and conservation holds.
    let spec = MacroSpec::default();
    check(
        "rejected requests charge zero on all four ledgers",
        cases(12),
        pairs(vecs(usizes(0..4), 1..18), usizes(1..4)),
        |(ops, burst)| {
            let build = || {
                let mut cfg = FleetConfig {
                    num_macros: 1,
                    coresident: true,
                    execution: ExecutionMode::Twin,
                    ..FleetConfig::default()
                };
                cfg.qos.insert(
                    "m1".into(),
                    QosSpec {
                        burst: *burst as u64,
                        ..QosSpec::default()
                    },
                );
                let mut fleet = QosFleet::new(&cfg, &spec);
                for (i, s) in [0.04, 0.03, 0.05].iter().enumerate() {
                    fleet
                        .register(&format!("m{i}"), vgg9().scaled(*s), false)
                        .unwrap();
                }
                fleet
            };
            let img = vec![0.5f32; 64];
            // Run 1: record which submits were admitted.
            let mut fleet = build();
            let mut admitted_ops = Vec::with_capacity(ops.len());
            for &op in ops {
                if op < 3 {
                    let a = fleet.submit(&format!("m{op}"), vec![img.clone()]).unwrap();
                    admitted_ops.push(a.is_admitted());
                } else {
                    let _ = fleet.dispatch_next().unwrap();
                    admitted_ops.push(true);
                }
            }
            fleet.drain().unwrap();
            let full = fleet.snapshot();
            // Run 2: the same script minus the rejected submits.
            let mut replay = build();
            for (&op, &keep) in ops.iter().zip(&admitted_ops) {
                if op < 3 {
                    if keep {
                        let a = replay.submit(&format!("m{op}"), vec![img.clone()]).unwrap();
                        assert!(a.is_admitted(), "replay re-admits the same script");
                    }
                } else {
                    let _ = replay.dispatch_next().unwrap();
                }
            }
            replay.drain().unwrap();
            let lean = replay.snapshot();
            full.reload_cycles == lean.reload_cycles
                && full.migration_cycles == lean.migration_cycles
                && full.aggregate() == lean.aggregate()
                && full.tenant_aggregate() == lean.tenant_aggregate()
                && full.twin_load_cycles() == lean.twin_load_cycles()
                && full.reload_cycles == full.macro_load_cycles()
                && full.twin_load_cycles() == full.reload_cycles
        },
    );
}

#[test]
fn prop_trace_replay_reproduces_all_four_ledgers() {
    // Any interleaved submit/dispatch/compact script through a traced
    // rate-limited twin fleet: the LedgerAuditor — fed the event stream
    // online, or replaying the ring offline — re-derives every ledger
    // (fleet, per-macro, per-tenant, twin, and the activation-buffer
    // ledger) bit-exactly against the final snapshot, with a monotone
    // clock and nothing dropped.
    let spec = MacroSpec::default();
    check(
        "trace replay reproduces all four ledgers",
        cases(12),
        pairs(vecs(usizes(0..5), 1..18), usizes(1..4)),
        |(ops, burst)| {
            let mut cfg = FleetConfig {
                num_macros: 1,
                coresident: true,
                execution: ExecutionMode::Twin,
                ..FleetConfig::default()
            };
            cfg.qos.insert(
                "m1".into(),
                QosSpec {
                    burst: *burst as u64,
                    ..QosSpec::default()
                },
            );
            let mut fleet = QosFleet::new(&cfg, &spec);
            let trace = FleetTrace::default();
            fleet.fleet_mut().set_trace(Some(trace.sink()));
            for (i, s) in [0.04, 0.03, 0.05].iter().enumerate() {
                fleet
                    .register(&format!("m{i}"), vgg9().scaled(*s), false)
                    .unwrap();
            }
            let img = vec![0.5f32; 64];
            for &op in ops {
                if op < 3 {
                    let _ = fleet.submit(&format!("m{op}"), vec![img.clone()]).unwrap();
                } else if op == 3 {
                    let _ = fleet.dispatch_next().unwrap();
                } else {
                    let _ = fleet.fleet_mut().compact();
                }
            }
            fleet.drain().unwrap();
            let snap = fleet.snapshot();
            let online = trace.audit.lock().unwrap().verify(&snap);
            let log = trace.log.lock().unwrap();
            let offline = LedgerAuditor::replay(log.events());
            let offline_report = offline.verify(&snap);
            // Buffer-traffic conservation: the offline replay re-derives
            // the same totals the fleet booked, every served image was
            // twin-executed (fleet == twin), and the per-tenant split sums
            // back to the fleet total.
            let tenant_buffer_total = (0..3).fold(BufferTraffic::default(), |mut acc, i| {
                acc.absorb(offline.tenant_buffer(&format!("m{i}")));
                acc
            });
            online.pass
                && offline_report.pass
                && log.dropped() == 0
                && offline.fleet_load_cycles() == snap.reload_cycles
                && offline.fleet_migration_cycles() == snap.migration_cycles
                && offline.fleet_buffer() == snap.buffer_fleet
                && offline.twin_buffer() == snap.buffer_twin
                && snap.buffer_twin == snap.buffer_fleet
                && tenant_buffer_total == snap.buffer_fleet
                && offline.clock_regressions() == 0
        },
    );
}

#[test]
fn prop_dedup_trace_replay_reproduces_all_four_ledgers() {
    // Any interleaved serve/retire/compact script over a shared-backbone
    // family (base + 3 derived heads + 2 unrelated tenants, overlapping
    // column content, a pool too small for all of them) under
    // content-addressed dedup: the online audit, the offline replay of
    // the recorded stream, and the snapshot must agree bit-exactly on
    // every view — four cycle ledgers plus the shared-span re-derivation
    // — and physically resident bitlines never exceed the number of
    // distinct column contents across resident tenants.
    let spec = MacroSpec::default();
    check(
        "dedup trace replay reproduces all four ledgers",
        cases(12),
        vecs(usizes(0..8), 1..22),
        |ops| {
            let cfg = FleetConfig {
                num_macros: 1,
                dedup: true,
                ..FleetConfig::default()
            };
            let mut fleet = Fleet::new(&cfg, &spec);
            let trace = FleetTrace::default();
            fleet.set_trace(Some(trace.sink()));
            fleet.register("base", vgg9().scaled(0.04), false).unwrap(); // 108 BLs
            for i in 0..3 {
                fleet.register_derived(&format!("h{i}"), "base", false).unwrap();
            }
            fleet.register("solo", vgg9().scaled(0.03), false).unwrap(); // 82 BLs
            fleet.register("big", vgg9().scaled(0.05), false).unwrap(); // 139 BLs
            let img = vec![0.5f32; 64];
            let names = ["base", "h0", "h1", "h2", "solo", "big"];
            for &op in ops {
                match op {
                    0..=5 => {
                        fleet.serve_batch(names[op], &[img.clone()]).unwrap();
                    }
                    6 => {
                        let _ = fleet.compact().unwrap();
                    }
                    _ => {
                        // Registry churn: retiring the base is refused
                        // while any head borrows its columns; solo has
                        // no borrowers so its retire/re-register cycle
                        // always goes through.
                        fleet.retire("solo").unwrap();
                        fleet.register("solo", vgg9().scaled(0.03), false).unwrap();
                    }
                }
            }
            let snap = fleet.snapshot();
            let online = trace.audit.lock().unwrap().verify(&snap);
            let log = trace.log.lock().unwrap();
            let offline = LedgerAuditor::replay(log.events());
            let offline_report = offline.verify(&snap);
            // Physical residency: own spans tile exactly the occupied
            // columns, and never exceed the distinct column contents
            // across resident tenants (sharing only ever shrinks; a
            // duplicate column *within* one tenant is the one case that
            // legitimately keeps an extra physical copy, counted as
            // `surplus`).
            let occupied: usize = snap.occupied_bls.iter().sum();
            let mut distinct = std::collections::BTreeSet::new();
            let mut surplus = 0usize;
            for name in names {
                if fleet.is_resident(name) {
                    let w = fleet.registry().get(name).unwrap().weights.clone().unwrap();
                    let mut within = std::collections::BTreeSet::new();
                    for col in &w.columns {
                        let key = (column_hash(col), col.len());
                        within.insert(key);
                        distinct.insert(key);
                    }
                    surplus += w.columns.len() - within.len();
                }
            }
            online.pass
                && offline_report.pass
                && log.dropped() == 0
                && offline.events() == trace.audit.lock().unwrap().events()
                && offline.fleet_load_cycles() == snap.reload_cycles
                && offline.shared_borrowed_bls() == snap.dedup_shared_bls as u64
                && offline.shared_avoided_cycles() == snap.dedup_shared_cycles
                && snap.reload_cycles == snap.macro_load_cycles()
                && snap.reload_cycles == snap.tenant_load_cycles()
                && snap.dedup_resident_bls() == occupied
                && snap.dedup_resident_bls() <= distinct.len() + surplus
                && offline.clock_regressions() == 0
        },
    );
}

#[test]
fn prop_dataflow_buffer_order_holds_for_any_scale() {
    // The closed-form buffer-traffic model, over arbitrary Stage-1
    // scaling ratios: every loop ordering writes the same activation
    // words (each output is produced exactly once), and reads are
    // monotone — tap-reuse ≤ spatial-first ≤ pixel-first, with tap-reuse
    // strictly winning whenever some layer has a >1×1 kernel overlap.
    check(
        "tap-reuse ≤ spatial-first ≤ pixel-first on the buffer ledger",
        cases(40),
        usizes(1..40),
        |&pct| {
            let arch = vgg9().scaled(pct as f64 / 100.0);
            let pf = model_buffer_traffic(&arch, DataflowKind::PixelFirst);
            let sf = model_buffer_traffic(&arch, DataflowKind::SpatialFirst);
            let tr = model_buffer_traffic(&arch, DataflowKind::TapReuse);
            pf.writes == sf.writes
                && sf.writes == tr.writes
                && tr.reads <= sf.reads
                && sf.reads <= pf.reads
                && tr.reads < pf.reads
        },
    );
}

#[test]
fn prop_concurrent_runtime_matches_virtual_clock_twin() {
    // The work-stealing runtime's equivalence contract, over ARBITRARY
    // interleaved submit/dispatch/compact scripts on a rate-limited
    // twin-executing fleet: the concurrent runtime (forward passes on
    // worker threads, admission overlapped with in-flight compute) and
    // the sequential virtual-clock QosFleet make IDENTICAL decisions —
    //   * the same per-submit admission verdicts,
    //   * the same batch outcomes in the same dispatch order,
    //   * bit-exact 4-ledger totals and QoS tenant ledgers,
    //   * byte-identical trace event streams (the reorder sink merges
    //     the overlapped emission back into op order),
    //   * and the LedgerAuditor passes on the merged concurrent trace.
    let spec = MacroSpec::default();
    check(
        "concurrent runtime ≡ sequential virtual-clock twin",
        cases(10),
        pairs(vecs(usizes(0..5), 1..18), usizes(1..4)),
        |(ops, burst)| {
            let cfg = {
                let mut cfg = FleetConfig {
                    num_macros: 2,
                    coresident: true,
                    execution: ExecutionMode::Twin,
                    ..FleetConfig::default()
                };
                cfg.qos.insert(
                    "m1".into(),
                    QosSpec {
                        burst: *burst as u64,
                        ..QosSpec::default()
                    },
                );
                cfg
            };
            let mut seq = QosFleet::new(&cfg, &spec);
            let seq_trace = FleetTrace::default();
            seq.fleet_mut().set_trace(Some(seq_trace.sink()));
            let mut con = ConcurrentFleet::new(&cfg, &spec, 3);
            let con_trace = FleetTrace::default();
            con.set_trace(Some(con_trace.sink()));
            for (i, s) in [0.04, 0.03, 0.05].iter().enumerate() {
                seq.register(&format!("m{i}"), vgg9().scaled(*s), false).unwrap();
                con.register(&format!("m{i}"), vgg9().scaled(*s), false).unwrap();
            }
            let img = vec![0.5f32; 64];
            for &op in ops {
                if op < 3 {
                    let a = seq
                        .submit(&format!("m{op}"), vec![img.clone()])
                        .unwrap();
                    let b = con
                        .submit(&format!("m{op}"), vec![img.clone()])
                        .unwrap();
                    if a != b {
                        return false; // admission decisions must agree
                    }
                } else if op == 3 {
                    let _ = seq.dispatch_next().unwrap();
                    let _ = con.dispatch_next().unwrap();
                } else {
                    let _ = seq.fleet_mut().compact();
                    let _ = con.compact();
                }
            }
            let seq_out = seq.drain().unwrap();
            let con_out = con.drain().unwrap();
            let outcomes_match = seq_out.len() == con_out.len()
                && seq_out.iter().zip(&con_out).all(|(a, b)| {
                    a.model == b.model
                        && a.batch == b.batch
                        && a.classes == b.classes
                        && a.logits == b.logits
                        && a.device_cycles == b.device_cycles
                        && a.reload_cycles == b.reload_cycles
                        && a.migration_cycles == b.migration_cycles
                        && a.evicted == b.evicted
                });
            let ss = seq.snapshot();
            let cs = con.snapshot();
            let ledgers_match = ss.reload_cycles == cs.reload_cycles
                && ss.migration_cycles == cs.migration_cycles
                && ss.aggregate() == cs.aggregate()
                && ss.tenant_aggregate() == cs.tenant_aggregate()
                && ss.twin_load_cycles() == cs.twin_load_cycles()
                && ss.twin_migration_cycles() == cs.twin_migration_cycles()
                && ss.qos_totals() == cs.qos_totals();
            let seq_events: Vec<_> =
                seq_trace.log.lock().unwrap().events().cloned().collect();
            let con_events: Vec<_> =
                con_trace.log.lock().unwrap().events().cloned().collect();
            let audit = con_trace.audit.lock().unwrap().verify(&cs);
            outcomes_match
                && ledgers_match
                && seq_events == con_events
                && audit.pass
                && cs.reload_cycles == cs.macro_load_cycles()
                && cs.reload_cycles == cs.tenant_load_cycles()
        },
    );
}

#[test]
fn prop_ring_membership_changes_remap_only_the_affected_arc() {
    // The consistent-hash guarantee, over random vnode counts, ring
    // sizes, and tenant populations: adding a pool only pulls tenants
    // onto the NEW pool (everyone else keeps their home), removing it
    // restores the exact prior routing, and removing an original member
    // only moves the tenants that were homed on it.
    check(
        "ring add/remove moves only the affected arc",
        cases(100),
        triples(usizes(1..33), usizes(2..9), usizes(1..120)),
        |&(vnodes, pools, tenants)| {
            let mut ring = HashRing::new(vnodes);
            for p in 0..pools {
                ring.add_pool(p);
            }
            let names: Vec<String> = (0..tenants).map(|i| format!("tenant-{i}")).collect();
            let before: Vec<usize> = names.iter().map(|n| ring.route(n).unwrap()).collect();
            // Adding a pool may only move tenants onto the added pool.
            ring.add_pool(pools);
            let mid: Vec<usize> = names.iter().map(|n| ring.route(n).unwrap()).collect();
            let add_ok = mid
                .iter()
                .zip(&before)
                .all(|(&new, &old)| new == old || new == pools);
            // Removing it hands every taken arc back to its prior owner.
            ring.remove_pool(pools);
            let restored: Vec<usize> = names.iter().map(|n| ring.route(n).unwrap()).collect();
            // Removing an original member only moves ITS tenants.
            let victim = tenants % pools;
            ring.remove_pool(victim);
            let after: Vec<usize> = names.iter().map(|n| ring.route(n).unwrap()).collect();
            let remove_ok = after
                .iter()
                .zip(&before)
                .all(|(&new, &old)| (new == old) == (old != victim));
            add_ok && restored == before && remove_ok
        },
    );
}

#[test]
fn prop_shard_trace_replay_reproduces_all_five_ledgers() {
    // Any serve/migrate script (shed policy armed) over a sharded twin
    // fleet: each pool's auditor re-derives its four ledgers from that
    // pool's event stream alone — online and replayed offline — and
    // replaying the shard-level MigratePool sub-script alone re-derives
    // the transfer ledger. Five ledgers, bit-exact, nothing dropped.
    let spec = MacroSpec::default();
    check(
        "shard trace replay reproduces all five ledgers",
        cases(10),
        pairs(vecs(usizes(0..6), 1..16), usizes(2..4)),
        |(ops, pools)| {
            let cfg = FleetConfig {
                pools: *pools,
                num_macros: 1,
                coresident: true,
                execution: ExecutionMode::Twin,
                shed_threshold: 0.9,
                ..FleetConfig::default()
            };
            let mut shard = ShardedFleet::new(&cfg, &spec);
            let pool_traces: Vec<FleetTrace> =
                (0..shard.num_pools()).map(|_| FleetTrace::default()).collect();
            for (p, t) in pool_traces.iter().enumerate() {
                shard.pool_mut(p).set_trace(Some(t.sink()));
            }
            let shard_trace = FleetTrace::default();
            shard.set_trace(Some(shard_trace.sink()));
            for (i, s) in [0.04, 0.03, 0.05].iter().enumerate() {
                shard
                    .register(&format!("m{i}"), vgg9().scaled(*s), false)
                    .unwrap();
            }
            let img = vec![0.5f32; 64];
            for &op in ops {
                let name = format!("m{}", op % 3);
                if op < 3 {
                    let _ = shard.serve_batch(&name, &[img.clone()]);
                } else {
                    let _ = shard.migrate_tenant(&name, op % *pools);
                }
            }
            let snap = shard.snapshot();
            // The four per-pool ledgers, online and offline.
            let pools_ok = pool_traces.iter().enumerate().all(|(p, t)| {
                let online = t.audit.lock().unwrap().verify(&snap.pools[p]);
                let log = t.log.lock().unwrap();
                let offline = LedgerAuditor::replay(log.events());
                online.pass && offline.verify(&snap.pools[p]).pass && log.dropped() == 0
            });
            // The fifth: replay the MigratePool sub-script alone.
            let log = shard_trace.log.lock().unwrap();
            let offline = LedgerAuditor::replay(log.events());
            pools_ok
                && offline.verify_transfers(&snap).pass
                && offline.fleet_transfer_cycles() == snap.transfer_cycles
                && offline.transfers() == snap.transfers
                && log.dropped() == 0
        },
    );
}

#[test]
fn prop_qos_rate_limit_bounds_throughput() {
    // The token-bucket invariant, exactly: admitted requests never
    // exceed the burst capacity plus the refill earned by the virtual
    // clock (milli-token ledger: admitted·1000 ≤ max(burst,1)·1000 +
    // clock·rate).
    let spec = MacroSpec::default();
    check(
        "rate-limited throughput ≤ token-bucket bound",
        cases(25),
        triples(vecs(usizes(0..2), 1..30), usizes(0..4), usizes(1..6)),
        |(ops, rate, burst)| {
            let mut cfg = FleetConfig {
                num_macros: 2,
                coresident: true,
                ..FleetConfig::default()
            };
            cfg.qos.insert(
                "m".into(),
                QosSpec {
                    rate_per_kcycle: *rate as u64,
                    burst: *burst as u64,
                    ..QosSpec::default()
                },
            );
            let mut fleet = QosFleet::new(&cfg, &spec);
            fleet.register("m", vgg9().scaled(0.04), false).unwrap();
            let img = vec![0.5f32; 64];
            for &op in ops {
                if op == 0 {
                    let _ = fleet.submit("m", vec![img.clone()]).unwrap();
                } else {
                    let _ = fleet.dispatch_next().unwrap();
                }
            }
            let totals = fleet.snapshot().qos_totals();
            let clock = fleet.fleet().qos().now();
            totals.admitted * 1000 <= (*burst as u64).max(1) * 1000 + clock * *rate as u64
        },
    );
}
