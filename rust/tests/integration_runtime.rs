//! Integration: the PJRT runtime against real AOT artifacts, and the
//! bit-exact CIM digital twin against the python-emitted parity vectors.
//!
//! These tests **skip** (pass with a notice) when `artifacts/` has not
//! been built (`make artifacts`) so `cargo test` works from a clean tree.

use std::path::{Path, PathBuf};

use cim_adapt::cim::{CimMacro, WeightCell};
use cim_adapt::config::MacroSpec;
use cim_adapt::data::{SynthCifar, NUM_CLASSES};
use cim_adapt::runtime::ModelRuntime;
use cim_adapt::util::json::Json;

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("vgg9_edge_meta.json").exists()
}

#[test]
fn runtime_loads_and_classifies() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = ModelRuntime::load(&artifacts_dir(), "vgg9_edge").expect("load runtime");
    assert!(rt.variants().contains(&"b1"));
    // One image through b1.
    let img = SynthCifar::sample(3, 0);
    let logits = rt.infer("b1", &img.data).expect("infer");
    assert_eq!(logits.len(), NUM_CLASSES);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn runtime_batch_variant_consistent_with_single() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let rt = ModelRuntime::load(&artifacts_dir(), "vgg9_edge").expect("load");
    if !rt.variants().contains(&"b8") {
        return;
    }
    // Same image replicated: batch logits must equal single-image logits.
    let img = SynthCifar::sample(5, 2);
    let single = rt.infer("b1", &img.data).unwrap();
    let mut batch = Vec::new();
    for _ in 0..8 {
        batch.extend_from_slice(&img.data);
    }
    let all = rt.infer("b8", &batch).unwrap();
    for row in all.chunks(NUM_CLASSES) {
        for (a, b) in row.iter().zip(&single) {
            assert!((a - b).abs() < 1e-4, "batch/single diverge: {a} vs {b}");
        }
    }
}

#[test]
fn runtime_accuracy_matches_recorded_p2() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let rt = ModelRuntime::load(&artifacts_dir(), "vgg9_edge").expect("load");
    let recorded = rt.meta.results.get("p2_acc").as_f64().unwrap_or(0.0);
    // Classify 80 fresh images (indices beyond any quick-preset training
    // range) and compare against the recorded accuracy.
    let n = 80usize;
    let mut correct = 0usize;
    for k in 0..n {
        let cls = k % NUM_CLASSES;
        let img = SynthCifar::sample(cls, 5000 + k as u64);
        let pred = rt.classify("b1", &img.data).unwrap()[0];
        if pred == cls {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    // Shape check, not exact: fresh-sample accuracy should be in the same
    // regime as the recorded test accuracy.
    assert!(
        acc >= recorded - 0.25,
        "serving accuracy {acc:.2} far below recorded {recorded:.2}"
    );
    assert!(acc > 1.5 / NUM_CLASSES as f64, "barely above chance: {acc}");
}

#[test]
fn pallas_variant_agrees_with_jnp_variant() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let rt = ModelRuntime::load(&artifacts_dir(), "vgg9_edge").expect("load");
    if !rt.variants().contains(&"pallas_b1") {
        return;
    }
    // The Pallas-kernel export and the jnp export encode identical
    // arithmetic; logits must agree tightly.
    for k in 0..5u64 {
        let img = SynthCifar::sample((k % 10) as usize, 99 + k);
        let a = rt.infer("b1", &img.data).unwrap();
        let b = rt.infer("pallas_b1", &img.data).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3, "pallas/jnp diverge: {x} vs {y}");
        }
    }
}

#[test]
fn cim_twin_matches_python_parity_vectors() {
    let path = artifacts_dir().join("parity_vectors.json");
    if !path.exists() {
        eprintln!("SKIP: parity vectors not built");
        return;
    }
    let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let cases = j.get("cim_matmul").as_arr().expect("cases");
    assert!(!cases.is_empty());
    for (ci, case) in cases.iter().enumerate() {
        let m = case.get("m").as_usize().unwrap();
        let k = case.get("k").as_usize().unwrap();
        let n = case.get("n").as_usize().unwrap();
        let seg = case.get("seg").as_usize().unwrap();
        let s_adc = case.get("s_adc").as_f64().unwrap() as f32;
        let grab = |key: &str| -> Vec<i64> {
            case.get(key)
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap() as i64)
                .collect()
        };
        let xs = grab("x_codes");
        let ws = grab("w_codes");
        let expect = grab("out_codes");

        // Lay the weights out segment-major, as the packer does, on a
        // macro wide enough for all columns of this case.
        let num_segs = k.div_ceil(seg);
        let spec = MacroSpec {
            bitlines: (num_segs * n).max(256),
            ..MacroSpec::default()
        };
        let mut mac = CimMacro::new(spec, 1.0, s_adc);
        for s in 0..num_segs {
            let lo = s * seg;
            let hi = (lo + seg).min(k);
            let cols: Vec<Vec<WeightCell>> = (0..n)
                .map(|j| {
                    (lo..hi)
                        .map(|r| WeightCell::saturating(ws[r * n + j] as i32, 4))
                        .collect()
                })
                .collect();
            mac.load_columns(s * n, &cols);
        }
        for row in 0..m {
            let seg_codes: Vec<Vec<i32>> = (0..num_segs)
                .map(|s| {
                    let lo = s * seg;
                    let hi = (lo + seg).min(k);
                    (lo..hi).map(|c| xs[row * k + c] as i32).collect()
                })
                .collect();
            // segmented_matvec returns scaled floats; with s_w = 1 the
            // value is code_sum * s_adc → divide back to get codes.
            let out = mac.segmented_matvec(&seg_codes, n, 1.0, false);
            for (jcol, &o) in out.iter().enumerate() {
                let got = (o / s_adc).round() as i64;
                let want = expect[row * n + jcol];
                assert_eq!(
                    got, want,
                    "case {ci} out[{row},{jcol}]: rust {got} vs python {want}"
                );
            }
        }
    }
}

#[test]
fn lsq_parity_vectors() {
    let path = artifacts_dir().join("parity_vectors.json");
    if !path.exists() {
        eprintln!("SKIP: parity vectors not built");
        return;
    }
    let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let case = j.get("lsq");
    let step = case.get("step").as_f64().unwrap() as f32;
    let ws: Vec<f32> = case
        .get("w")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    let qs: Vec<i32> = case
        .get("q")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as i32)
        .collect();
    let t = cim_adapt::quant::lsq::LsqTensor::quantize(&ws, step, 4);
    assert_eq!(t.codes, qs, "rust LSQ codes differ from python");
}
