//! End-to-end observability tests: the PR-5 QoS overload scenario run
//! with tracing on. The online `LedgerAuditor` must re-derive all four
//! ledgers (fleet, per-macro, per-tenant, twin) bit-exactly from the
//! event stream, the Chrome export must round-trip through `Json::parse`
//! with one complete track per macro and per tenant, and the same
//! scenario run twice must serialize byte-identically.

use cim_adapt::arch::vgg9;
use cim_adapt::config::{ExecutionMode, FleetConfig, MacroSpec};
use cim_adapt::fleet::{FleetSnapshot, QosClass, QosFleet};
use cim_adapt::obs::{
    ascii_timeline, events_from_chrome, EventKind, FleetTrace, LedgerAuditor,
};
use cim_adapt::util::json::Json;

const TENANTS: [&str; 3] = ["hi", "lo1", "lo2"];

/// The three-tenant overload mix from `benches/micro_fleet.rs` (and
/// `examples/fleet_qos.rs`), traced: `hi` is latency-critical, all three
/// overload a 1-macro co-resident twin pool, so every round forces
/// reloads the trace must account for.
fn traced_overload(rounds: usize, capacity: usize) -> (FleetTrace, FleetSnapshot) {
    let spec = MacroSpec::default();
    let mut cfg = FleetConfig {
        num_macros: 1,
        coresident: true,
        execution: ExecutionMode::Twin,
        qos_aging_cycles: 1_000_000,
        ..FleetConfig::default()
    };
    for (name, class) in [
        ("hi", QosClass::Interactive),
        ("lo1", QosClass::Batch),
        ("lo2", QosClass::Batch),
    ] {
        cfg.qos.entry(name.to_string()).or_default().class = class;
    }
    let mut fleet = QosFleet::new(&cfg, &spec);
    let trace = FleetTrace::new(capacity);
    fleet.fleet_mut().set_trace(Some(trace.sink()));
    for (name, s) in [("hi", 0.04), ("lo1", 0.03), ("lo2", 0.05)] {
        fleet.register(name, vgg9().scaled(s), false).unwrap();
    }
    let img = vec![0.5f32; 64];
    for _ in 0..rounds {
        for m in ["lo1", "lo2", "hi"] {
            let _ = fleet.submit(m, vec![img.clone(), img.clone()]).unwrap();
        }
    }
    fleet.drain().unwrap();
    let snap = fleet.snapshot();
    (trace, snap)
}

fn tenant_names() -> Vec<String> {
    TENANTS.iter().map(|s| s.to_string()).collect()
}

#[test]
fn audit_rederives_all_four_ledgers_online_and_offline() {
    let (trace, snap) = traced_overload(6, 1 << 16);
    let online = trace.audit.lock().unwrap().verify(&snap);
    assert!(
        online.pass,
        "online audit must pass, first divergence: {:?}",
        online.first_divergence
    );
    assert!(online.checks > 0 && online.events > 0);
    // Offline replay of the recorded ring reaches the same verdict and
    // the same derived totals as the fleet's own ledgers.
    let log = trace.log.lock().unwrap();
    assert_eq!(log.dropped(), 0, "default-sized ring must hold the scenario");
    let offline = LedgerAuditor::replay(log.events());
    let report = offline.verify(&snap);
    assert!(report.pass, "offline replay diverged: {:?}", report.first_divergence);
    assert_eq!(offline.fleet_load_cycles(), snap.reload_cycles);
    assert_eq!(offline.fleet_migration_cycles(), snap.migration_cycles);
    assert_eq!(offline.clock_regressions(), 0, "virtual clock must be monotone");
    // The scenario actually exercised the ledger-bearing paths.
    assert!(snap.reload_cycles > 0, "overload must force reloads");
    assert!(log.count(EventKind::RegionReload) > 0);
    assert!(log.count(EventKind::TwinPass) > 0);
    assert!(log.count(EventKind::DispatchEnd) > 0);
}

#[test]
fn audit_flags_a_tampered_stream() {
    let (trace, snap) = traced_overload(3, 1 << 16);
    let log = trace.log.lock().unwrap();
    let mut events: Vec<_> = log.events().cloned().collect();
    // Inflate one analytic reload charge: the re-derived fleet ledger no
    // longer matches the snapshot and the audit must name the divergence.
    let idx = events
        .iter()
        .position(|e| e.kind == EventKind::RegionReload && !e.twin)
        .expect("scenario records reloads");
    events[idx].cycles += 1;
    let report = LedgerAuditor::replay(&events).verify(&snap);
    assert!(!report.pass, "a tampered charge must fail the audit");
    assert!(report.first_divergence.is_some());
}

#[test]
fn chrome_trace_roundtrips_with_one_complete_track_per_macro_and_tenant() {
    let (trace, _snap) = traced_overload(4, 1 << 16);
    let dump = trace.chrome(1, &tenant_names()).dump();
    let parsed = Json::parse(&dump).expect("Chrome export must be valid JSON");
    let arr = parsed.get("traceEvents").as_arr().unwrap();
    // Track declarations: 2 process_name metas + 1 macro + 3 tenants
    // (no compaction in this scenario, so no synthetic "fleet" tenant).
    let track_labels: Vec<&str> = arr
        .iter()
        .filter(|e| e.get("ph").as_str() == Some("M"))
        .filter_map(|e| e.at(&["args", "name"]).as_str())
        .collect();
    assert_eq!(
        track_labels,
        vec![
            "cim macros",
            "cim tenants",
            "macro 0",
            "tenant hi",
            "tenant lo1",
            "tenant lo2"
        ]
    );
    // Every recorded event round-trips bit-exactly through the args
    // payloads, in order.
    let back = events_from_chrome(&parsed).unwrap();
    let originals: Vec<_> = trace.log.lock().unwrap().events().cloned().collect();
    assert_eq!(back, originals);
}

#[test]
fn identical_runs_export_byte_identical_traces() {
    let (t1, s1) = traced_overload(5, 1 << 16);
    let (t2, s2) = traced_overload(5, 1 << 16);
    assert_eq!(s1.reload_cycles, s2.reload_cycles, "scenario is deterministic");
    assert_eq!(
        t1.chrome(1, &tenant_names()).dump(),
        t2.chrome(1, &tenant_names()).dump(),
        "Chrome export must be byte-identical across identical runs"
    );
    assert_eq!(
        t1.prometheus(Some(true)),
        t2.prometheus(Some(true)),
        "Prometheus export must be byte-identical across identical runs"
    );
}

#[test]
fn ring_bound_holds_but_lifetime_counts_survive() {
    let (trace, _snap) = traced_overload(6, 8);
    let log = trace.log.lock().unwrap();
    assert!(log.len() <= 8, "ring must never exceed its capacity");
    assert!(log.dropped() > 0, "a 6-round overload overflows an 8-slot ring");
    assert_eq!(
        log.total(),
        log.len() as u64 + log.dropped(),
        "per-kind counters must keep counting past eviction"
    );
}

#[test]
fn ascii_timeline_renders_the_traced_scenario() {
    let (trace, _snap) = traced_overload(4, 1 << 16);
    let events: Vec<_> = trace.log.lock().unwrap().events().cloned().collect();
    let t = ascii_timeline(&events, 64);
    assert!(t.starts_with("virtual clock 0.."));
    assert!(t.contains("macro   0 |"), "the pool's one macro gets a row");
    assert!(t.contains('R'), "reloads paint R cells");
    assert!(t.ends_with("R reload · M migration · P twin pass\n"));
}
