//! Bench: regenerate Table II (λ grid → macro usage extremes).

use cim_adapt::report::table2;
use cim_adapt::util::bench::{black_box, Runner};

fn main() {
    let mut r = Runner::new("table2_macro_usage");
    let t = table2(std::path::Path::new("artifacts"));
    r.table(&format!("{}", t.rendered));
    r.bench("table2 grid (2 λ × 4 seeds)", || {
        black_box(table2(std::path::Path::new("artifacts")));
    });
    r.finish();
}
