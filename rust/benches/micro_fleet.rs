//! Microbench: fleet-level serving — hot-swap churn, residency hits, and
//! the headline trade the subsystem exists to expose: under the same
//! request mix, a morphed (compressed) model sustains strictly fewer
//! reload cycles than its uncompressed ancestor, because it fits the
//! pool where the ancestor pages.
//!
//! Also measures the fragmentation story end to end: a register/retire
//! churn under first-fit vs best-fit vs best-fit + online defrag, with
//! the defrag win asserted in twin cycles (fewer spans per tenant, fewer
//! load events, lower load+migration+pass total).
//!
//! The `dedup_scenario` arm runs the shared-backbone family (one base +
//! 16 derived heads) with and without content-addressed dedup: the
//! deduped pool must fit the whole family and sustain strictly fewer
//! reload cycles than private-copy placement, with the five-view audit
//! (four cycle ledgers + shared-span re-derivation) passing and the
//! counters byte-deterministic.
//!
//! Emits `BENCH_fleet.json` (see `report::write_bench_summary`) so the
//! perf trajectory is tracked across PRs.

use std::collections::BTreeSet;

use cim_adapt::arch::by_name;
use cim_adapt::cim::MacroStats;
use cim_adapt::config::{DataflowKind, ExecutionMode, FleetConfig, MacroSpec, MorphConfig};
use cim_adapt::data::SynthCifar;
use cim_adapt::fleet::{
    scratch_allocs, EvictionPolicy, Fleet, FleetServer, QosClass, QosFleet, SchedMode,
    ShardedFleet,
};
use cim_adapt::latency::{model_cost, BufferTraffic};
use cim_adapt::mapping::{pack_model, FitPolicyKind};
use cim_adapt::morph::flow::morph_flow_synthetic;
use cim_adapt::obs::{events_from_chrome, EventKind, FleetTrace, LedgerAuditor};
use cim_adapt::report::write_bench_summary;
use cim_adapt::util::bench::{black_box, Runner};
use cim_adapt::util::json::Json;

fn tenant(model: &str, target_bl: usize, seed: u64) -> cim_adapt::arch::ModelArch {
    morph_flow_synthetic(
        &by_name(model).unwrap(),
        &MacroSpec::default(),
        &MorphConfig {
            target_bl,
            ..MorphConfig::default()
        },
        0.4,
        seed,
    )
    .arch
}

fn cfg(num_macros: usize) -> FleetConfig {
    FleetConfig {
        num_macros,
        max_batch: 8,
        batch_timeout_us: 200,
        queue_depth: 4096,
        policy: EvictionPolicy::Lru,
        ..FleetConfig::default()
    }
}

/// Outcome of the co-residency scenario under one placement granularity.
struct CoresidencyRun {
    reload_cycles: u64,
    resident_macros: usize,
    utilization: f64,
    twin_load_cycles: u64,
}

/// Two fractional-macro tenants alternating on a **1-macro** pool: with
/// co-residency both live on the macro's columns (one partial swap each);
/// with whole-macro placement they evict each other every round. Under
/// twin execution the same mix also materializes the weights and runs
/// every image through the simulated macro.
fn coresidency_mix(coresident: bool, execution: ExecutionMode, rounds: usize) -> CoresidencyRun {
    let spec = MacroSpec::default();
    let fleet_cfg = FleetConfig {
        num_macros: 1,
        coresident,
        execution,
        ..cfg(1)
    };
    let mut fleet = Fleet::new(&fleet_cfg, &spec);
    fleet.register("a", by_name("vgg9").unwrap().scaled(0.04), false).unwrap();
    fleet.register("b", by_name("vgg9").unwrap().scaled(0.03), false).unwrap();
    let batch: Vec<Vec<f32>> = (0..4).map(|k| SynthCifar::sample(k, k as u64).data).collect();
    for _ in 0..rounds {
        fleet.serve_batch("a", &batch).unwrap();
        fleet.serve_batch("b", &batch).unwrap();
    }
    let snap = fleet.snapshot();
    assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
    assert_eq!(snap.reload_cycles, snap.tenant_load_cycles());
    let resident_macros: BTreeSet<usize> = snap
        .resident
        .iter()
        .flat_map(|p| p.macros())
        .collect();
    CoresidencyRun {
        reload_cycles: snap.reload_cycles,
        resident_macros: resident_macros.len(),
        utilization: snap.utilization(),
        twin_load_cycles: snap.twin_load_cycles(),
    }
}

/// Outcome of the register/retire churn scenario under one fit policy
/// (and optionally online defrag) — all deterministic twin-pool counters.
struct ChurnRun {
    spans_per_tenant: f64,
    fragmentation: f64,
    reload_cycles: u64,
    migration_cycles: u64,
    reload_events: u64,
    compactions: u64,
    /// Twin busy cycles: load + migration + executed pass cycles — the
    /// headline "reload+pass" figure the defrag win is measured in.
    twin_total_cycles: u64,
}

/// Register/retire churn on a 2-macro co-resident **twin** pool: four
/// tenants land, two retire (leaving two holes), a fifth arrives, and
/// the surviving mix then serves `rounds` alternating batches. Under
/// first-fit the fifth tenant splinters across the holes — every span is
/// a separately-charged load event and an extra macro pass per segment
/// it splits, on every image. Best-fit lands it whole; the defrag arm
/// additionally compacts the pool (threshold-triggered) before the
/// placement, paying one-time migration cycles to keep every tenant
/// contiguous.
fn churn_mix(fit: FitPolicyKind, defrag_threshold: f64, rounds: usize) -> ChurnRun {
    let spec = MacroSpec::default();
    let fleet_cfg = FleetConfig {
        num_macros: 2,
        coresident: true,
        execution: ExecutionMode::Twin,
        fit,
        defrag_threshold,
        ..cfg(2)
    };
    let mut fleet = Fleet::new(&fleet_cfg, &spec);
    let scaled = |s: f64| by_name("vgg9").unwrap().scaled(s);
    let batch: Vec<Vec<f32>> = (0..4).map(|k| SynthCifar::sample(k, k as u64).data).collect();
    for (name, s) in [("a", 0.04), ("b", 0.03), ("c", 0.05), ("d", 0.04)] {
        fleet.register(name, scaled(s), false).unwrap();
        fleet.serve_batch(name, &batch).unwrap();
    }
    fleet.retire("b").unwrap();
    fleet.retire("d").unwrap();
    fleet.register("e", scaled(0.05), false).unwrap();
    for _ in 0..rounds {
        for m in ["a", "c", "e"] {
            fleet.serve_batch(m, &batch).unwrap();
        }
    }
    let snap = fleet.snapshot();
    // Both charge classes conserve across all four ledgers.
    assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
    assert_eq!(snap.reload_cycles, snap.tenant_load_cycles());
    assert_eq!(snap.twin_load_cycles(), snap.reload_cycles);
    assert_eq!(snap.migration_cycles, snap.macro_migration_cycles());
    assert_eq!(snap.migration_cycles, snap.tenant_migration_cycles());
    assert_eq!(snap.twin_migration_cycles(), snap.migration_cycles);
    let frag = snap.fragmentation();
    let twin = MacroStats::aggregate(snap.twin_stats.iter());
    ChurnRun {
        spans_per_tenant: frag.mean_spans_per_tenant(),
        fragmentation: frag.score(),
        reload_cycles: snap.reload_cycles,
        migration_cycles: snap.migration_cycles,
        reload_events: snap.aggregate().reloads,
        compactions: snap.compactions,
        twin_total_cycles: twin.busy_cycles(),
    }
}

/// Outcome of the three-tenant overload scenario under one dispatch
/// arm — all deterministic virtual-clock counters.
struct QosRun {
    /// Fleet-wide hot-swap reload cycles.
    reload_cycles: u64,
    /// Load cycles attributed to the high-priority tenant.
    hi_load_cycles: u64,
    /// The high-priority tenant's total attributed twin cycles
    /// (compute + load + migration) — the "served with fewer total twin
    /// cycles" acceptance figure.
    hi_busy_cycles: u64,
    /// Virtual cycles the high-priority tenant's requests waited.
    hi_queue_delay_cycles: u64,
    /// Twin-pool busy cycles over the whole arm (load + migration +
    /// executed passes).
    total_twin_cycles: u64,
    admitted: u64,
    rejected: u64,
    deferred: u64,
}

/// Three tenants overloading a **1-macro** co-resident twin pool: `hi`
/// (108 BLs, latency-critical) interleaved behind `lo1` (82) and `lo2`
/// (139) for `rounds` rounds — together they exceed the macro, so the
/// dispatch order decides who thrashes. Three arms share the exact same
/// submit script:
///
/// * `fifo` — strict arrival order: every round reloads all three
///   tenants (the pre-QoS overload pathology).
/// * `priority` — `hi` is `Interactive`, the rest `Batch`: each tenant
///   is served as one consecutive run, so each loads exactly once.
/// * `admission` — priorities plus an admission budget sized so any
///   hot-swap projects over it (non-resident queues defer behind
///   resident ones, bounded by the anti-starvation terms) and a hard
///   token-bucket cap on `lo2` (only its first 2 batches are admitted).
///
/// `examples/fleet_qos.rs` mirrors this scenario for the README's worked
/// example — keep the two in sync (this bench is the CI-gated source of
/// truth).
///
/// When `trace` is given, every fleet/QoS event is recorded into it and
/// the online [`LedgerAuditor`] must re-derive all four ledgers from the
/// event stream bit-exactly (asserted against the final snapshot here).
fn qos_overload_mix(
    sched: SchedMode,
    classes: bool,
    admission: bool,
    rounds: usize,
    trace: Option<&FleetTrace>,
) -> QosRun {
    let spec = MacroSpec::default();
    let scaled = |s: f64| by_name("vgg9").unwrap().scaled(s);
    let (hi, lo1, lo2) = (scaled(0.04), scaled(0.03), scaled(0.05));
    // Budget: every resident 2-image pass fits, every hot-swap projects
    // over (the smallest footprint is 82 columns > the 40-cycle slack).
    let pass2 = |a: &cim_adapt::arch::ModelArch| model_cost(a, &spec).pass_cycles(2);
    let budget = pass2(&hi).max(pass2(&lo1)).max(pass2(&lo2)) + 40;
    let mut fleet_cfg = FleetConfig {
        num_macros: 1,
        coresident: true,
        execution: ExecutionMode::Twin,
        sched,
        // Large aging window: the arms demonstrate pure class ordering;
        // the anti-starvation *defer* bound still guarantees progress.
        qos_aging_cycles: 1_000_000,
        admit_budget_cycles: if admission { budget } else { 0 },
        ..cfg(1)
    };
    if classes {
        for (name, class) in [
            ("hi", QosClass::Interactive),
            ("lo1", QosClass::Batch),
            ("lo2", QosClass::Batch),
        ] {
            fleet_cfg.qos.entry(name.to_string()).or_default().class = class;
        }
    }
    if admission {
        // Hard cap (burst without refill): only lo2's first 4 requests
        // (2 batches) are ever admitted.
        let lo2_spec = fleet_cfg.qos.entry("lo2".to_string()).or_default();
        lo2_spec.burst = 4;
    }
    let mut fleet = QosFleet::new(&fleet_cfg, &spec);
    if let Some(t) = trace {
        fleet.fleet_mut().set_trace(Some(t.sink()));
    }
    fleet.register("hi", hi.clone(), false).unwrap();
    fleet.register("lo1", lo1.clone(), false).unwrap();
    fleet.register("lo2", lo2.clone(), false).unwrap();
    if admission {
        // The budget really separates the two cases for every tenant.
        for (name, arch) in [("hi", &hi), ("lo1", &lo1), ("lo2", &lo2)] {
            let reload = fleet.fleet().registry().get(name).unwrap().bls_needed() as u64;
            assert!(pass2(arch) <= budget, "resident pass must fit the budget");
            assert!(pass2(arch) + reload > budget, "hot-swaps must project over");
        }
    }
    let batch: Vec<Vec<f32>> = (0..2).map(|k| SynthCifar::sample(k, k as u64).data).collect();
    for _ in 0..rounds {
        for m in ["lo1", "lo2", "hi"] {
            let _ = fleet.submit(m, batch.clone()).unwrap();
        }
    }
    let outcomes = fleet.drain().unwrap();
    let snap = fleet.snapshot();
    // All four ledgers agree, with or without QoS in the loop.
    assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
    assert_eq!(snap.reload_cycles, snap.tenant_load_cycles());
    assert_eq!(snap.twin_load_cycles(), snap.reload_cycles);
    let totals = snap.qos_totals();
    // Every admitted request was served — nothing starves.
    let served: u64 = outcomes.iter().map(|o| o.batch as u64).sum();
    assert_eq!(served, totals.admitted);
    if let Some(t) = trace {
        let report = t.audit.lock().unwrap().verify(&snap);
        assert!(
            report.pass,
            "online four-ledger audit must re-derive the snapshot: {:?}",
            report.first_divergence
        );
    }
    let tenants: std::collections::BTreeMap<&str, &MacroStats> = snap
        .tenant_stats
        .iter()
        .map(|(n, s)| (n.as_str(), s))
        .collect();
    let qos: std::collections::BTreeMap<&str, _> = snap
        .qos_stats
        .iter()
        .map(|(n, s)| (n.as_str(), *s))
        .collect();
    QosRun {
        reload_cycles: snap.reload_cycles,
        hi_load_cycles: tenants["hi"].load_cycles,
        hi_busy_cycles: tenants["hi"].busy_cycles(),
        hi_queue_delay_cycles: qos["hi"].queue_delay_cycles,
        total_twin_cycles: MacroStats::aggregate(snap.twin_stats.iter()).busy_cycles(),
        admitted: totals.admitted,
        rejected: totals.rejected,
        deferred: totals.deferred,
    }
}

fn qos_json(r: &QosRun) -> Json {
    Json::obj()
        .with("reload_cycles", r.reload_cycles)
        .with("hi_load_cycles", r.hi_load_cycles)
        .with("hi_busy_cycles", r.hi_busy_cycles)
        .with("hi_queue_delay_cycles", r.hi_queue_delay_cycles)
        .with("total_twin_cycles", r.total_twin_cycles)
        .with("admitted", r.admitted)
        .with("rejected", r.rejected)
        .with("deferred", r.deferred)
}

fn churn_json(r: &ChurnRun) -> Json {
    Json::obj()
        .with("spans_per_tenant", r.spans_per_tenant)
        .with("fragmentation", r.fragmentation)
        .with("reload_cycles", r.reload_cycles)
        .with("migration_cycles", r.migration_cycles)
        .with("reload_events", r.reload_events)
        .with("compactions", r.compactions)
        .with("twin_total_cycles", r.twin_total_cycles)
}

/// Outcome of the sharded-serving overload scenario under one arm — all
/// deterministic shard-wide counters.
struct ShardRun {
    /// Reload + migration + inter-pool transfer cycles — the figure the
    /// arms compete on (`ShardSnapshot::total_movement_cycles`).
    movement_cycles: u64,
    reload_cycles: u64,
    migration_cycles: u64,
    transfer_cycles: u64,
    /// Charged (resident) cross-pool migrations executed.
    transfers: u64,
    /// Highest registered-footprint pressure across pools right after
    /// registration — i.e. what the hash skew dealt, before any shed.
    max_pressure: f64,
    /// Every pool's four-ledger audit plus the shard transfer audit.
    audit_pass: bool,
    /// The full shard snapshot, serialized — byte-compared across runs
    /// for the determinism gate.
    counters: String,
}

/// 64 tenants of ~82 columns each hashed across `pools` pools of 3
/// macros (768 columns per pool) and served round-robin. The ring's arc
/// skew piles 54 of the 64 onto one pool and 10 onto a second — far
/// past capacity — so a pool stuck with its hash-dealt tenants reloads
/// every one of them on every round. The arms in `main`:
///
/// * single pool (`pools = 1`, shed off) — the pre-sharding hardware:
///   all 64 tenants thrash 3 macros.
/// * static shard (`pools = 8`, shed off) — the skewed homes are final;
///   the overloaded pools thrash forever while six pools idle.
/// * sharded + migration (`pools = 8`, `shed_threshold = 0.9`) — the
///   hot pools shed their hottest tenants to the coldest pools, paying
///   bounded one-time transfer charges; once every pool fits its
///   tenants, steady state reloads nothing.
///
/// Each pool carries its own trace/auditor (four-ledger re-derivation
/// from its event stream) and the shard sink carries the transfer
/// auditor (fifth ledger from `MigratePool` events alone).
fn shard_overload_mix(pools: usize, shed_threshold: f64, rounds: usize) -> ShardRun {
    let spec = MacroSpec::default();
    let fleet_cfg = FleetConfig {
        pools,
        num_macros: 3,
        coresident: true,
        shed_threshold,
        ..cfg(3)
    };
    let mut shard = ShardedFleet::new(&fleet_cfg, &spec);
    let pool_traces: Vec<FleetTrace> =
        (0..shard.num_pools()).map(|_| FleetTrace::default()).collect();
    for (p, t) in pool_traces.iter().enumerate() {
        shard.pool_mut(p).set_trace(Some(t.sink()));
    }
    let shard_trace = FleetTrace::default();
    shard.set_trace(Some(shard_trace.sink()));
    let arch = by_name("vgg9").unwrap().scaled(0.03); // 82 columns
    let names: Vec<String> = (0..64).map(|i| format!("t{i:02}")).collect();
    for n in &names {
        shard.register(n, arch.clone(), false).unwrap();
    }
    let max_pressure = (0..shard.num_pools())
        .map(|p| shard.pressure(p))
        .fold(0.0_f64, f64::max);
    let batch = vec![SynthCifar::sample(1, 7).data];
    for _ in 0..rounds {
        for n in &names {
            shard.serve_batch(n, &batch).unwrap();
        }
    }
    let snap = shard.snapshot();
    let mut audit_pass = true;
    for (p, t) in pool_traces.iter().enumerate() {
        audit_pass &= t.audit.lock().unwrap().verify(&snap.pools[p]).pass;
    }
    audit_pass &= shard_trace.audit.lock().unwrap().verify_transfers(&snap).pass;
    ShardRun {
        movement_cycles: snap.total_movement_cycles(),
        reload_cycles: snap.total_reload_cycles(),
        migration_cycles: snap.total_migration_cycles(),
        transfer_cycles: snap.transfer_cycles,
        transfers: snap.transfers,
        max_pressure,
        audit_pass,
        counters: snap.to_json().dump(),
    }
}

fn shard_json(r: &ShardRun) -> Json {
    Json::obj()
        .with("movement_cycles", r.movement_cycles)
        .with("reload_cycles", r.reload_cycles)
        .with("migration_cycles", r.migration_cycles)
        .with("transfer_cycles", r.transfer_cycles)
        .with("transfers", r.transfers)
        .with("max_pressure", r.max_pressure)
}

/// Outcome of the shared-backbone scenario under one placement mode —
/// all deterministic counters.
struct DedupRun {
    reload_cycles: u64,
    evictions: u64,
    /// Logical bitlines resident tenants would need as private copies
    /// (0 with dedup off).
    logical_bls: usize,
    /// Physical bitlines actually resident under dedup.
    resident_bls: usize,
    shared_bls: usize,
    shared_cycles: u64,
    ratio: f64,
    /// Online five-view audit: four cycle ledgers plus the shared-span
    /// re-derivation from SharedLoad/SharedRelease events.
    audit_pass: bool,
    /// Full snapshot serialization, byte-compared for the determinism
    /// gate.
    counters: String,
}

/// One shared base (108-column vgg9) plus 16 fine-tuned heads — same
/// backbone cell-for-cell, divergent classifier — round-robin on a
/// **3-macro** (768-column) pool. With private copies the 17 tenants
/// need 17 × 108 = 1836 columns and thrash evictions every round; with
/// content-addressed dedup each head borrows the backbone by reference
/// and keeps only its delta resident, so the whole family fits and
/// steady state reloads nothing.
fn dedup_backbone_mix(dedup: bool, rounds: usize) -> DedupRun {
    let spec = MacroSpec::default();
    let fleet_cfg = FleetConfig {
        num_macros: 3,
        coresident: true,
        dedup,
        ..cfg(3)
    };
    let trace = FleetTrace::default();
    let mut fleet = Fleet::new(&fleet_cfg, &spec);
    fleet.set_trace(Some(trace.sink()));
    fleet
        .register("base", by_name("vgg9").unwrap().scaled(0.04), false)
        .unwrap();
    let names: Vec<String> = std::iter::once("base".to_string())
        .chain((0..16).map(|i| format!("h{i:02}")))
        .collect();
    for n in &names[1..] {
        fleet.register_derived(n, "base", false).unwrap();
    }
    let batch = vec![SynthCifar::sample(3, 17).data];
    for _ in 0..rounds {
        for n in &names {
            fleet.serve_batch(n, &batch).unwrap();
        }
    }
    let snap = fleet.snapshot();
    // Four-ledger conservation holds with or without borrowing.
    assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
    assert_eq!(snap.reload_cycles, snap.tenant_load_cycles());
    let audit = trace.audit.lock().unwrap().verify(&snap);
    assert!(
        audit.pass,
        "online audit must re-derive every view: {:?}",
        audit.first_divergence
    );
    DedupRun {
        reload_cycles: snap.reload_cycles,
        evictions: snap.evictions,
        logical_bls: snap.dedup_logical_bls,
        resident_bls: snap.dedup_resident_bls(),
        shared_bls: snap.dedup_shared_bls,
        shared_cycles: snap.dedup_shared_cycles,
        ratio: snap.dedup_ratio(),
        audit_pass: audit.pass,
        counters: snap.to_json().dump(),
    }
}

/// Run an alternating primary/co request mix on a deterministic core and
/// return total reload cycles.
fn reload_cycles_under_mix(
    primary: cim_adapt::arch::ModelArch,
    co: cim_adapt::arch::ModelArch,
    rounds: usize,
) -> u64 {
    let spec = MacroSpec::default();
    let mut fleet = Fleet::new(&cfg(4), &spec);
    fleet.register("primary", primary, false).unwrap();
    fleet.register("co", co, false).unwrap();
    let batch: Vec<Vec<f32>> = (0..4).map(|k| SynthCifar::sample(k, k as u64).data).collect();
    for _ in 0..rounds {
        fleet.serve_batch("primary", &batch).unwrap();
        fleet.serve_batch("co", &batch).unwrap();
    }
    let snap = fleet.snapshot();
    assert_eq!(
        snap.reload_cycles,
        snap.macro_load_cycles(),
        "reload accounting must conserve"
    );
    snap.reload_cycles
}

fn main() {
    let mut r = Runner::new("micro_fleet");
    let spec = MacroSpec::default();
    let img = SynthCifar::sample(0, 0);

    // --- throughput benches over the threaded fleet ----------------------
    // Residency-hit path: one tenant, always resident after first touch.
    let h = FleetServer::start(&cfg(4), &spec);
    h.register("edge", tenant("vgg9", 512, 11), false).unwrap();
    r.bench("submit+wait roundtrip (resident tenant)", || {
        let t = h.submit("edge", img.data.clone()).unwrap();
        black_box(t.wait().unwrap());
    });
    r.bench_throughput("pipelined 64-deep (resident tenant)", "req", || {
        let tickets: Vec<_> = (0..64)
            .map(|_| h.submit("edge", img.data.clone()).unwrap())
            .collect();
        for t in tickets {
            black_box(t.wait().unwrap());
        }
        64
    });
    h.shutdown();

    // Hot-swap churn: three 2-macro tenants on 4 macros, round-robin →
    // every dispatch may swap.
    let h = FleetServer::start(&cfg(4), &spec);
    for (i, m) in ["vgg9", "vgg16", "resnet18"].iter().enumerate() {
        h.register(m, tenant(m, 512, 20 + i as u64), false).unwrap();
    }
    r.bench_throughput("round-robin 3 tenants (hot-swap churn)", "req", || {
        let tickets: Vec<_> = (0..48)
            .map(|k| {
                let m = ["vgg9", "vgg16", "resnet18"][k % 3];
                h.submit(m, img.data.clone()).unwrap()
            })
            .collect();
        for t in tickets {
            black_box(t.wait().unwrap());
        }
        48
    });
    let (metrics, churn_snap) = h.shutdown();
    r.table(&format!(
        "churn fleet: {} hot-swaps, {} evictions, {} reload cycles over {} requests",
        churn_snap.hot_swaps, churn_snap.evictions, churn_snap.reload_cycles, metrics.completed
    ));

    // --- the compression trade (deterministic cycle counts) --------------
    // Same alternating mix vs the same co-tenant; only the primary's
    // compression differs. 93%-compressed VGG9 (512 BLs ≈ 2 macros) fits
    // beside the co-tenant; uncompressed VGG9 (151 macros) pages.
    let rounds = 16;
    let co = tenant("vgg16", 512, 30);
    let morphed_cycles = reload_cycles_under_mix(tenant("vgg9", 512, 31), co.clone(), rounds);
    let uncompressed_cycles =
        reload_cycles_under_mix(by_name("vgg9").unwrap(), co, rounds);
    r.table(&format!(
        "reload cycles over {rounds} alternating rounds: morphed {morphed_cycles} vs uncompressed {uncompressed_cycles} ({:.1}× fewer)",
        uncompressed_cycles as f64 / morphed_cycles.max(1) as f64
    ));
    assert!(
        morphed_cycles < uncompressed_cycles,
        "morphed variant must sustain strictly fewer reload cycles \
         ({morphed_cycles} vs {uncompressed_cycles})"
    );

    // --- fractional-macro co-residency (deterministic cycle counts) ------
    // Two tenants that together fit ONE macro's columns: co-residency
    // keeps both resident on fewer macros than whole-macro placement
    // needs, with strictly fewer reload cycles and higher utilization.
    let co = coresidency_mix(true, ExecutionMode::Analytic, rounds);
    let whole = coresidency_mix(false, ExecutionMode::Analytic, rounds);
    let spec_ = MacroSpec::default();
    let whole_macros_needed: usize = [0.04, 0.03]
        .iter()
        .map(|&s| pack_model(&by_name("vgg9").unwrap().scaled(s), &spec_).num_macros)
        .sum();
    r.table(&format!(
        "co-residency over {rounds} alternating rounds: {} reload cycles on {} macro(s) \
         at {:.1}% utilization vs whole-macro {} cycles needing {} macros at {:.1}%",
        co.reload_cycles,
        co.resident_macros,
        co.utilization * 100.0,
        whole.reload_cycles,
        whole_macros_needed,
        whole.utilization * 100.0
    ));
    assert!(
        co.reload_cycles < whole.reload_cycles,
        "co-residency must sustain strictly fewer reload cycles \
         ({} vs {})",
        co.reload_cycles,
        whole.reload_cycles
    );
    assert!(
        co.resident_macros < whole_macros_needed,
        "co-residents must share macros ({} vs {} needed whole)",
        co.resident_macros,
        whole_macros_needed
    );
    assert!(
        co.utilization > whole.utilization,
        "co-residency must lift fleet utilization ({:.3} vs {:.3})",
        co.utilization,
        whole.utilization
    );

    // --- twin execution (deterministic cycle counts) ----------------------
    // The same co-resident mix with the digital twin materializing every
    // placement and executing each image on the simulated macro: the
    // twin's charged load cycles must equal the analytic ledger exactly,
    // and the placement economics must not change.
    let twin = coresidency_mix(true, ExecutionMode::Twin, rounds);
    r.table(&format!(
        "twin execution over {rounds} alternating rounds: {} twin load cycles \
         (analytic ledger {}, delta {}) at {:.1}% utilization",
        twin.twin_load_cycles,
        twin.reload_cycles,
        twin.twin_load_cycles as i64 - twin.reload_cycles as i64,
        twin.utilization * 100.0
    ));
    assert_eq!(
        twin.twin_load_cycles, twin.reload_cycles,
        "twin-charged load cycles must equal the analytic ledger"
    );
    assert_eq!(
        twin.reload_cycles, co.reload_cycles,
        "twin execution must not change placement economics"
    );

    // --- churn + fit policies + online defrag (deterministic) -------------
    // Same register/retire churn, three arms: first-fit fragments the
    // late arrival, best-fit lands it whole, best-fit + defrag also
    // compacts the pool first (one-time migration). The defragged pool
    // must serve the same mix with fewer spans per tenant and fewer
    // total twin cycles (load + migration + passes) than first-fit.
    let ff = churn_mix(FitPolicyKind::FirstFit, 0.0, rounds);
    let bf = churn_mix(FitPolicyKind::BestFit, 0.0, rounds);
    let dg = churn_mix(FitPolicyKind::BestFit, 0.3, rounds);
    r.table(&format!(
        "churn scenario over {rounds} rounds: first-fit {:.2} spans/tenant, {} twin cycles, \
         {} load events | best-fit {:.2}, {}, {} | defrag {:.2}, {}, {} (+{} migration, \
         {} compaction(s))",
        ff.spans_per_tenant,
        ff.twin_total_cycles,
        ff.reload_events,
        bf.spans_per_tenant,
        bf.twin_total_cycles,
        bf.reload_events,
        dg.spans_per_tenant,
        dg.twin_total_cycles,
        dg.reload_events,
        dg.migration_cycles,
        dg.compactions
    ));
    assert!(
        dg.spans_per_tenant < ff.spans_per_tenant,
        "defrag must reduce mean spans per tenant ({:.3} vs {:.3})",
        dg.spans_per_tenant,
        ff.spans_per_tenant
    );
    assert!(
        bf.spans_per_tenant <= ff.spans_per_tenant,
        "best-fit must not fragment more than first-fit"
    );
    assert!(
        dg.twin_total_cycles < ff.twin_total_cycles,
        "defrag must win on total twin reload+pass cycles ({} vs {})",
        dg.twin_total_cycles,
        ff.twin_total_cycles
    );
    assert!(
        dg.reload_events < ff.reload_events,
        "defragged placements load in fewer span writes"
    );
    assert!(dg.compactions >= 1 && dg.migration_cycles > 0, "defrag really ran");
    assert_eq!(ff.migration_cycles, 0, "no defrag in the first-fit arm");

    // --- QoS overload: fifo vs priority vs priority+admission ------------
    // Same interleaved overload script on a 1-macro twin pool; only the
    // dispatch arm changes. Priority must kill the high-priority
    // tenant's reload thrash (it is served as one run and loads once);
    // admission must also cut the fleet's total twin cycles by refusing
    // the over-rate tenant and deferring over-budget swaps.
    let ff_q = qos_overload_mix(SchedMode::Fifo, false, false, rounds / 2, None);
    let pr_q = qos_overload_mix(SchedMode::Qos, true, false, rounds / 2, None);
    let ad_q = qos_overload_mix(SchedMode::Qos, true, true, rounds / 2, None);
    r.table(&format!(
        "qos overload over {} rounds: fifo hi {} load / {} delay cycles, {} total reload | \
         priority hi {} / {}, {} | admission hi {} / {}, {} ({} rejected, {} deferrals)",
        rounds / 2,
        ff_q.hi_load_cycles,
        ff_q.hi_queue_delay_cycles,
        ff_q.reload_cycles,
        pr_q.hi_load_cycles,
        pr_q.hi_queue_delay_cycles,
        pr_q.reload_cycles,
        ad_q.hi_load_cycles,
        ad_q.hi_queue_delay_cycles,
        ad_q.reload_cycles,
        ad_q.rejected,
        ad_q.deferred
    ));
    assert!(
        pr_q.hi_load_cycles < ff_q.hi_load_cycles,
        "priority must kill the hi tenant's reload thrash ({} vs {})",
        pr_q.hi_load_cycles,
        ff_q.hi_load_cycles
    );
    assert!(
        pr_q.hi_busy_cycles < ff_q.hi_busy_cycles,
        "the priority tenant must be served with fewer total twin cycles \
         ({} vs {})",
        pr_q.hi_busy_cycles,
        ff_q.hi_busy_cycles
    );
    assert!(
        pr_q.hi_queue_delay_cycles < ff_q.hi_queue_delay_cycles,
        "the priority tenant must wait fewer cycles ({} vs {})",
        pr_q.hi_queue_delay_cycles,
        ff_q.hi_queue_delay_cycles
    );
    assert!(
        pr_q.reload_cycles < ff_q.reload_cycles,
        "priority runs must reduce total reload cycles under churn"
    );
    assert!(
        ad_q.reload_cycles < ff_q.reload_cycles && ad_q.total_twin_cycles < ff_q.total_twin_cycles,
        "admission must reduce total reload and twin cycles ({} vs {}, {} vs {})",
        ad_q.reload_cycles,
        ff_q.reload_cycles,
        ad_q.total_twin_cycles,
        ff_q.total_twin_cycles
    );
    assert!(ad_q.rejected > 0, "the rate-capped tenant must see rejections");
    assert!(ad_q.deferred > 0, "over-budget swaps must be deferred");
    assert_eq!(ff_q.rejected, 0, "the fifo baseline admits everything");
    assert_eq!(ff_q.deferred, 0, "the fifo baseline never defers");
    assert_eq!(
        pr_q.admitted, ff_q.admitted,
        "priority changes order, not admission"
    );

    // --- deterministic tracing + online four-ledger audit -----------------
    // The admission arm again, twice, each run with a fresh trace bundle:
    // the online auditor must re-derive all four ledgers from the event
    // stream alone (asserted inside qos_overload_mix), the Chrome export
    // must round-trip through the JSON parser, and — because every event
    // is stamped from the virtual device clock — the two runs must
    // serialize byte-identically.
    let tenants: Vec<String> = ["hi", "lo1", "lo2"].iter().map(|s| s.to_string()).collect();
    let t1 = FleetTrace::default();
    let tr_q = qos_overload_mix(SchedMode::Qos, true, true, rounds / 2, Some(&t1));
    let t2 = FleetTrace::default();
    let _ = qos_overload_mix(SchedMode::Qos, true, true, rounds / 2, Some(&t2));
    assert_eq!(
        tr_q.reload_cycles, ad_q.reload_cycles,
        "tracing must observe the scenario, not perturb it"
    );
    let chrome1 = t1.chrome(1, &tenants).dump();
    let chrome2 = t2.chrome(1, &tenants).dump();
    let deterministic = chrome1 == chrome2;
    assert!(deterministic, "same scenario twice must trace byte-identically");
    assert_eq!(
        t1.prometheus(Some(true)),
        t2.prometheus(Some(true)),
        "Prometheus export must be deterministic too"
    );
    let parsed = Json::parse(&chrome1).expect("chrome trace must parse back");
    let events = events_from_chrome(&parsed).expect("chrome trace must decode");
    let (events_total, dropped, trace_counts) = {
        let log = t1.log.lock().unwrap();
        let mut counts = Json::obj().with("rounds", rounds / 2);
        for k in EventKind::ALL {
            counts = counts.with(k.as_str(), log.count(k));
        }
        (log.total(), log.dropped(), counts)
    };
    assert_eq!(dropped, 0, "the default ring must hold this scenario whole");
    assert_eq!(events.len() as u64, events_total, "round-trip must keep every event");
    // Offline replay of the round-tripped stream reproduces the online
    // auditor's ledgers (which the in-mix assert tied to the snapshot).
    let offline = LedgerAuditor::replay(&events);
    {
        let online = t1.audit.lock().unwrap();
        assert_eq!(offline.events(), online.events());
        assert_eq!(offline.fleet_load_cycles(), online.fleet_load_cycles());
        assert_eq!(offline.fleet_migration_cycles(), online.fleet_migration_cycles());
        assert_eq!(offline.clock_regressions(), 0);
    }
    r.table(&format!(
        "traced admission arm: {events_total} events, audit PASS, \
         chrome export deterministic across runs ({} bytes)",
        chrome1.len()
    ));

    // --- sharded serving: single pool vs static shard vs shed policy ------
    // 64 tenants hashed over 8 pools; the ring's arc skew overloads one
    // pool well past capacity. Static sharding leaves it thrashing
    // reloads every round; the shed policy pays bounded inter-pool
    // transfers once and then serves from residency. Competed on total
    // movement cycles (reload + migration + transfer), with the fifth
    // ledger conservation-audited and the counters byte-deterministic.
    let sh_single = shard_overload_mix(1, 0.0, rounds);
    let sh_static = shard_overload_mix(8, 0.0, rounds);
    let sh_migrate = shard_overload_mix(8, 0.9, rounds);
    let sh_repeat = shard_overload_mix(8, 0.9, rounds);
    r.table(&format!(
        "shard scenario over {rounds} rounds, 8 pools x 64 tenants: single-pool {} movement \
         cycles | static-shard {} (max pressure {:.2}) | sharded+migration {} \
         ({} charged transfers, {} transfer cycles)",
        sh_single.movement_cycles,
        sh_static.movement_cycles,
        sh_static.max_pressure,
        sh_migrate.movement_cycles,
        sh_migrate.transfers,
        sh_migrate.transfer_cycles
    ));
    assert!(
        sh_static.max_pressure > 1.0,
        "the hash skew must overload at least one pool (max pressure {:.3})",
        sh_static.max_pressure
    );
    assert!(
        sh_migrate.movement_cycles < sh_single.movement_cycles,
        "sharded+migration must beat the single pool on total movement cycles ({} vs {})",
        sh_migrate.movement_cycles,
        sh_single.movement_cycles
    );
    assert!(
        sh_migrate.movement_cycles < sh_static.movement_cycles,
        "migration must beat static sharding on total movement cycles ({} vs {})",
        sh_migrate.movement_cycles,
        sh_static.movement_cycles
    );
    assert!(
        sh_migrate.transfers > 0 && sh_migrate.transfer_cycles > 0,
        "the win must be bought through charged transfers, not luck"
    );
    assert_eq!(sh_static.transfer_cycles, 0, "no migration in the static arm");
    assert_eq!(sh_single.transfer_cycles, 0, "no migration on a single pool");
    assert!(
        sh_single.audit_pass && sh_static.audit_pass && sh_migrate.audit_pass,
        "per-pool four-ledger audits and the shard transfer audit must pass"
    );
    let shard_deterministic = sh_migrate.counters == sh_repeat.counters;
    assert!(
        shard_deterministic,
        "the same shard scenario twice must produce byte-identical counters"
    );

    // --- content-addressed dedup: shared backbone + 16 heads --------------
    // Identical round-robin script; only the placement mode changes.
    // Private copies can't fit the family (1836 of 768 columns) and
    // thrash; dedup keeps one backbone copy plus per-head deltas
    // resident, so the same mix sustains strictly fewer reload cycles.
    let dd_private = dedup_backbone_mix(false, rounds);
    let dd_shared = dedup_backbone_mix(true, rounds);
    let dd_repeat = dedup_backbone_mix(true, rounds);
    r.table(&format!(
        "dedup scenario over {rounds} rounds, 1 base + 16 heads on 3 macros: private {} \
         reload cycles ({} evictions) | dedup {} ({} evictions, {} logical bitlines in {} \
         physical = {:.2}x, {} borrowed, {} cycles avoided)",
        dd_private.reload_cycles,
        dd_private.evictions,
        dd_shared.reload_cycles,
        dd_shared.evictions,
        dd_shared.logical_bls,
        dd_shared.resident_bls,
        dd_shared.ratio,
        dd_shared.shared_bls,
        dd_shared.shared_cycles
    ));
    assert!(
        dd_shared.reload_cycles < dd_private.reload_cycles,
        "dedup must strictly beat private-copy placement on total reload cycles ({} vs {})",
        dd_shared.reload_cycles,
        dd_private.reload_cycles
    );
    assert!(
        dd_shared.ratio > 1.0,
        "the shared backbone must multiply capacity (ratio {:.3})",
        dd_shared.ratio
    );
    assert!(
        dd_shared.resident_bls < dd_shared.logical_bls,
        "physical residency must undercut the logical footprint ({} vs {})",
        dd_shared.resident_bls,
        dd_shared.logical_bls
    );
    assert_eq!(
        dd_shared.evictions, 0,
        "the deduped family must fit the pool without evictions"
    );
    assert!(
        dd_shared.shared_bls > 0 && dd_shared.shared_cycles > 0,
        "the win must come from live borrowed spans"
    );
    assert_eq!(dd_private.logical_bls, 0, "dedup stats stay zero with dedup off");
    assert!(
        dd_shared.audit_pass && dd_private.audit_pass,
        "the five-view audit must pass in both arms"
    );
    let dd_deterministic = dd_shared.counters == dd_repeat.counters;
    assert!(
        dd_deterministic,
        "the same dedup scenario twice must produce byte-identical counters"
    );

    // Twin forward throughput on a resident tenant (timing only).
    {
        let spec_ = MacroSpec::default();
        let twin_cfg = FleetConfig {
            num_macros: 1,
            coresident: true,
            execution: ExecutionMode::Twin,
            ..cfg(1)
        };
        let mut fleet = Fleet::new(&twin_cfg, &spec_);
        fleet
            .register("edge", by_name("vgg9").unwrap().scaled(0.04), false)
            .unwrap();
        fleet.serve_batch("edge", &[img.data.clone()]).unwrap();
        r.bench("twin forward (108-BL resident tenant)", || {
            black_box(fleet.infer_twin("edge", &img.data).unwrap());
        });
    }

    // --- dataflow scenario: loop orderings on the buffer ledger -----------
    // The same resident tenant under all three twin loop orderings: the
    // numerics and compute cycles are identical by construction (variants
    // change only the *charged* activation-buffer traffic), tap-reuse
    // strictly beats naive full-spatial on buffer reads, the twin's
    // compute cycles equal the analytic `computing_latency` exactly, the
    // steady-state forward allocates nothing, and an oversized tenant
    // still completes a twin-executed forward via load-on-demand paging.
    struct DataflowRun {
        logits: Vec<Vec<f32>>,
        twin_compute: u64,
        buffer: BufferTraffic,
        steady_allocs: u64,
        audit_pass: bool,
        snapshot_bytes: String,
    }
    let dataflow_arm = |kind: DataflowKind| -> DataflowRun {
        let spec_ = MacroSpec::default();
        let dcfg = FleetConfig {
            num_macros: 1,
            coresident: true,
            execution: ExecutionMode::Twin,
            dataflow: kind,
            ..cfg(1)
        };
        let trace = FleetTrace::default();
        let mut fleet = Fleet::new(&dcfg, &spec_);
        fleet.set_trace(Some(trace.sink()));
        fleet
            .register("edge", by_name("vgg9").unwrap().scaled(0.04), false)
            .unwrap();
        let batch = vec![img.data.clone()];
        // Warm-up pass grows the thread-local scratch to its high-water
        // mark (and pays the hot-swap); afterwards forwards are
        // allocation-free in steady state.
        fleet.serve_batch("edge", &batch).unwrap();
        let allocs_before = scratch_allocs();
        fleet.serve_batch("edge", &batch).unwrap();
        let out = fleet.serve_batch("edge", &batch).unwrap();
        let steady_allocs = scratch_allocs() - allocs_before;
        let snap = fleet.snapshot();
        let audit = trace.audit.lock().unwrap().verify(&snap);
        DataflowRun {
            logits: out.logits,
            twin_compute: snap.twin_stats.iter().map(|s| s.compute_cycles).sum(),
            buffer: snap.buffer_fleet,
            steady_allocs,
            audit_pass: audit.pass,
            snapshot_bytes: snap.to_json().dump(),
        }
    };
    let df_pf = dataflow_arm(DataflowKind::PixelFirst);
    let df_sf = dataflow_arm(DataflowKind::SpatialFirst);
    let df_tr = dataflow_arm(DataflowKind::TapReuse);
    let df_repeat = dataflow_arm(DataflowKind::TapReuse);
    let df_deterministic = df_tr.snapshot_bytes == df_repeat.snapshot_bytes;
    assert!(df_deterministic, "same dataflow arm twice must snapshot byte-identically");
    assert_eq!(df_pf.logits, df_sf.logits, "loop order must not change the numerics");
    assert_eq!(df_sf.logits, df_tr.logits, "loop order must not change the numerics");
    assert_eq!(df_pf.twin_compute, df_tr.twin_compute);
    let df_expected_compute = {
        let spec_ = MacroSpec::default();
        // 3 served images (1 warm-up + 2 steady) × the analytic latency.
        3 * model_cost(&by_name("vgg9").unwrap().scaled(0.04), &spec_).computing_latency as u64
    };
    let df_twin_equals_analytic = df_tr.twin_compute == df_expected_compute;
    assert!(
        df_twin_equals_analytic,
        "twin compute cycles must equal the analytic latency ({} vs {})",
        df_tr.twin_compute, df_expected_compute
    );
    assert_eq!(df_pf.buffer.writes, df_tr.buffer.writes, "writes are order-invariant");
    assert!(
        df_tr.buffer.reads < df_sf.buffer.reads && df_sf.buffer.reads < df_pf.buffer.reads,
        "tap-reuse must strictly beat spatial-first and naive full-spatial on reads \
         ({} vs {} vs {})",
        df_tr.buffer.reads,
        df_sf.buffer.reads,
        df_pf.buffer.reads
    );
    assert_eq!(df_tr.steady_allocs, 0, "steady-state forwards must not allocate");
    assert!(
        df_pf.audit_pass && df_sf.audit_pass && df_tr.audit_pass,
        "the buffer-ledger audit must pass in every variant"
    );
    // Oversized tenant: bigger than the whole pool, still twin-executed.
    let df_paged_executes = {
        let spec_ = MacroSpec::default();
        let mut fleet = Fleet::new(
            &FleetConfig {
                execution: ExecutionMode::Twin,
                ..cfg(4)
            },
            &spec_,
        );
        fleet
            .register("big", by_name("vgg9").unwrap().scaled(0.3), false)
            .unwrap();
        let bls = fleet.registry().get("big").unwrap().bls_needed();
        assert!(bls > 4 * spec_.bitlines, "tenant must exceed the pool");
        let out = fleet.serve_batch("big", &[img.data.clone()]).unwrap();
        let snap = fleet.snapshot();
        let twin_compute: u64 = snap.twin_stats.iter().map(|s| s.compute_cycles).sum();
        out.logits[0].iter().all(|v| v.is_finite())
            && !fleet.is_resident("big")
            && twin_compute > 0
            && snap.reload_cycles == bls as u64
    };
    assert!(df_paged_executes, "the oversized tenant must complete a paged twin forward");
    r.table(&format!(
        "dataflow scenario: buffer reads pixel-first {} | spatial-first {} | tap-reuse {} \
         (writes {} in all variants) | twin == analytic compute: {} | paged tenant executes: {}",
        df_pf.buffer.reads,
        df_sf.buffer.reads,
        df_tr.buffer.reads,
        df_tr.buffer.writes,
        df_twin_equals_analytic,
        df_paged_executes
    ));
    let df_json = |run: &DataflowRun| {
        Json::obj()
            .with("buffer_reads", run.buffer.reads)
            .with("buffer_writes", run.buffer.writes)
            .with("twin_compute_cycles", run.twin_compute)
    };

    // --- machine-readable summary ----------------------------------------
    let summary = Json::obj()
        .with("bench", "micro_fleet")
        .with("timings", r.results_json())
        .with("serving", metrics.to_json())
        .with("churn", churn_snap.to_json())
        .with("fleet_utilization", co.utilization)
        .with("fleet_fragmentation", ff.fragmentation)
        .with("fleet_spans_per_tenant", ff.spans_per_tenant)
        .with(
            "churn_scenario",
            Json::obj()
                .with("rounds", rounds)
                .with("first_fit", churn_json(&ff))
                .with("best_fit", churn_json(&bf))
                .with("defrag", churn_json(&dg))
                .with(
                    "defrag_win_cycles",
                    ff.twin_total_cycles - dg.twin_total_cycles,
                ),
        )
        .with(
            "qos_scenario",
            Json::obj()
                .with("rounds", rounds / 2)
                .with("fifo", qos_json(&ff_q))
                .with("priority", qos_json(&pr_q))
                .with("admission", qos_json(&ad_q))
                .with(
                    "priority_hi_win_cycles",
                    ff_q.hi_busy_cycles - pr_q.hi_busy_cycles,
                )
                .with(
                    "admission_reload_win_cycles",
                    ff_q.reload_cycles - ad_q.reload_cycles,
                ),
        )
        // Per-kind event counts from the traced admission arm, plus the
        // audit/determinism verdicts as 0/1 counters (the asserts above
        // abort the bench before this summary is written if either
        // fails, so a committed baseline always reads 1).
        .with(
            "trace_scenario",
            trace_counts
                .with("events_total", events_total)
                .with("audit_pass", 1u64)
                .with("deterministic", u64::from(deterministic)),
        )
        // Shard arms: audit/determinism verdicts as 0/1 counters, same
        // contract as trace_scenario (asserts abort before this summary
        // is written, so a committed baseline always reads 1).
        .with(
            "shard_scenario",
            Json::obj()
                .with("rounds", rounds)
                .with("pools", 8)
                .with("tenants", 64)
                .with("single_pool", shard_json(&sh_single))
                .with("static_shard", shard_json(&sh_static))
                .with("migration", shard_json(&sh_migrate))
                .with(
                    "migration_win_cycles",
                    sh_static.movement_cycles - sh_migrate.movement_cycles,
                )
                .with("audit_pass", 1u64)
                .with("deterministic", u64::from(shard_deterministic)),
        )
        // Dedup arms: exact reload/footprint counters per placement
        // mode, plus the audit/determinism verdicts as 0/1 counters
        // (same contract as trace_scenario: the asserts above abort the
        // bench before this summary is written, so a committed baseline
        // always reads 1).
        .with(
            "dedup_scenario",
            Json::obj()
                .with("rounds", rounds)
                .with("heads", 16)
                .with(
                    "private",
                    Json::obj().with("reload_cycles", dd_private.reload_cycles),
                )
                .with(
                    "dedup",
                    Json::obj()
                        .with("reload_cycles", dd_shared.reload_cycles)
                        .with("logical_bls", dd_shared.logical_bls)
                        .with("resident_bls", dd_shared.resident_bls)
                        .with("shared_bls", dd_shared.shared_bls)
                        .with("shared_cycles", dd_shared.shared_cycles),
                )
                .with(
                    "dedup_win_cycles",
                    dd_private.reload_cycles - dd_shared.reload_cycles,
                )
                .with("audit_pass", 1u64)
                .with("deterministic", u64::from(dd_deterministic)),
        )
        // Dataflow arms: exact buffer-ledger counters per loop ordering,
        // plus the equality/paging/allocation verdicts as 0/1 counters
        // (same contract as trace_scenario: the asserts above abort the
        // bench before this summary is written, so a committed baseline
        // always reads 1 — except steady_allocs, which reads 0).
        .with(
            "dataflow_scenario",
            Json::obj()
                .with("pixel_first", df_json(&df_pf))
                .with("spatial_first", df_json(&df_sf))
                .with("tap_reuse", df_json(&df_tr))
                .with(
                    "tap_reuse_win_reads",
                    df_pf.buffer.reads - df_tr.buffer.reads,
                )
                .with("twin_equals_analytic", u64::from(df_twin_equals_analytic))
                .with("paged_executes", u64::from(df_paged_executes))
                .with("steady_allocs", df_tr.steady_allocs)
                .with("audit_pass", 1u64)
                .with("deterministic", u64::from(df_deterministic)),
        )
        .with(
            "coresidency",
            Json::obj()
                .with("rounds", rounds)
                .with("coresident_reload_cycles", co.reload_cycles)
                .with("whole_macro_reload_cycles", whole.reload_cycles)
                .with(
                    "reload_advantage",
                    whole.reload_cycles as f64 / co.reload_cycles.max(1) as f64,
                )
                .with("coresident_macros", co.resident_macros)
                .with("whole_macros_needed", whole_macros_needed)
                .with("coresident_utilization", co.utilization)
                .with("whole_macro_utilization", whole.utilization),
        )
        .with(
            "compression_trade",
            Json::obj()
                .with("rounds", rounds)
                .with("morphed_reload_cycles", morphed_cycles)
                .with("uncompressed_reload_cycles", uncompressed_cycles)
                .with(
                    "reload_ratio",
                    uncompressed_cycles as f64 / morphed_cycles.max(1) as f64,
                ),
        )
        .with(
            "twin",
            Json::obj()
                .with("rounds", rounds)
                .with("reload_cycles", twin.reload_cycles)
                .with(
                    "ledger_delta",
                    twin.twin_load_cycles as i64 - twin.reload_cycles as i64,
                )
                .with("utilization", twin.utilization),
        );
    match write_bench_summary("fleet", &summary) {
        Ok(path) => r.table(&format!("(wrote {})", path.display())),
        Err(e) => r.table(&format!("(BENCH_fleet.json not written: {e})")),
    }
    r.finish();
}
