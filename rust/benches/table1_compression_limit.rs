//! Bench: regenerate Table I (model compression limit) and time the
//! shrink→expand machinery that produces it.

use cim_adapt::arch::vgg9;
use cim_adapt::config::MacroSpec;
use cim_adapt::morph::{expand_to_budget, prune_by_gamma, synthetic_gammas};
use cim_adapt::report::table1;
use cim_adapt::util::bench::{black_box, Runner};

fn main() {
    let mut r = Runner::new("table1_compression_limit");

    // The table itself (the paper artifact).
    let t = table1(std::path::Path::new("artifacts"));
    r.table(&format!("{}", t.rendered));

    // Microbench the pieces behind each row.
    let spec = MacroSpec::default();
    let seed = vgg9();
    let gammas = synthetic_gammas(&seed, 0.5, 3);
    r.bench("prune_by_gamma(vgg9)", || {
        black_box(prune_by_gamma(&seed, &gammas, 1e-2));
    });
    let pruned = prune_by_gamma(&seed, &gammas, 1e-2).arch;
    r.bench("expansion_search(vgg9 → 19k BLs, step 1e-3)", || {
        black_box(expand_to_budget(&pruned, &spec, 19_000, 0.001));
    });
    r.bench("table1 end-to-end (10 rows)", || {
        black_box(table1(std::path::Path::new("artifacts")));
    });
    r.finish();
}
