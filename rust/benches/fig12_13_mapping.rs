//! Bench: regenerate Figs. 12–13 (macro occupancy maps for VGG9 morphed
//! to 512 / 1024 bitlines) and time the packer + renderer.

use cim_adapt::arch::vgg9;
use cim_adapt::config::MacroSpec;
use cim_adapt::mapping::{pack_model, OccupancyGrid};
use cim_adapt::report::fig12_13;
use cim_adapt::util::bench::{black_box, Runner};

fn main() {
    let mut r = Runner::new("fig12_13_mapping");
    let out_dir = std::path::PathBuf::from("artifacts/figures");
    for bl in [512usize, 1024] {
        let f = fig12_13(bl, Some(&out_dir)).expect("figure");
        r.table(&format!("{}", f.rendered));
        if let Some(p) = &f.ppm_path {
            r.table(&format!("(wrote {})", p.display()));
        }
    }

    let spec = MacroSpec::default();
    let full = vgg9();
    r.bench("pack_model(vgg9 full, 151 macros)", || {
        black_box(pack_model(&full, &spec));
    });
    let map = pack_model(&vgg9().scaled(0.2), &spec);
    r.bench("occupancy_grids(vgg9×0.2)", || {
        black_box(OccupancyGrid::from_mapping(&map));
    });
    r.bench("fig12 end-to-end (morph+pack+render)", || {
        black_box(fig12_13(512, None).unwrap());
    });
    r.finish();
}
