//! Bench: regenerate Table V (comprehensive results for resnet18) — cost
//! columns full-scale/exact, plus timing of the morph flow behind it.

use cim_adapt::arch::by_name;
use cim_adapt::config::{MacroSpec, MorphConfig};
use cim_adapt::latency::model_cost;
use cim_adapt::morph::flow::morph_flow_synthetic;
use cim_adapt::report::table3_4_5;
use cim_adapt::util::bench::{black_box, Runner};

fn main() {
    let mut r = Runner::new("table5_resnet18");
    let t = table3_4_5("resnet18", std::path::Path::new("artifacts"));
    r.table(&format!("{}", t.rendered));

    let spec = MacroSpec::default();
    let arch = by_name("resnet18").unwrap();
    r.bench("cost_model(resnet18 full-scale)", || {
        black_box(model_cost(&arch, &spec));
    });
    let cfg = MorphConfig { target_bl: 4096, ..MorphConfig::default() };
    r.bench("morph_flow(resnet18 → 4096 BLs, 3 rounds)", || {
        black_box(morph_flow_synthetic(&arch, &spec, &cfg, 0.4, 11));
    });
    r.finish();
}
