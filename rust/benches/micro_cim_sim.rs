//! Microbench: the bit-exact CIM digital twin's hot paths — bitline MAC,
//! full macro passes, segmented matvec — the L3 per-request inner loops.

use cim_adapt::cim::{Adc, CimMacro, WeightCell};
use cim_adapt::config::MacroSpec;
use cim_adapt::util::bench::{black_box, Runner};
use cim_adapt::util::prng::Pcg;

fn main() {
    let mut r = Runner::new("micro_cim_sim");
    let spec = MacroSpec::default();
    let mut rng = Pcg::new(7);

    // A fully-loaded 256×256 macro.
    let mut mac = CimMacro::new(spec, 1.0, 16.0);
    let cols: Vec<Vec<WeightCell>> = (0..256)
        .map(|_| {
            (0..252)
                .map(|_| WeightCell::saturating(rng.gen_range(15) as i32 - 7, 4))
                .collect()
        })
        .collect();
    mac.load_columns(0, &cols);
    let codes: Vec<i32> = (0..252).map(|_| rng.gen_range(16) as i32).collect();

    r.bench("bitline_mac (252 rows)", || {
        black_box(mac.array.bitline_mac(0, &codes));
    });
    r.bench_throughput("macro pass (256 BL, 4 ADC rounds)", "conversions", || {
        black_box(mac.pass(&codes, 0, 256));
        256
    });

    // Segmented matvec: a 512-channel layer's worth (19 segments × 64).
    let mut big = CimMacro::new(MacroSpec { bitlines: 19 * 64, ..spec }, 1.0, 16.0);
    for s in 0..19usize {
        let cols: Vec<Vec<WeightCell>> = (0..64)
            .map(|_| {
                (0..252)
                    .map(|_| WeightCell::saturating(rng.gen_range(15) as i32 - 7, 4))
                    .collect()
            })
            .collect();
        big.load_columns(s * 64, &cols);
    }
    let seg_codes: Vec<Vec<i32>> = (0..19)
        .map(|_| (0..252).map(|_| rng.gen_range(16) as i32).collect())
        .collect();
    r.bench_throughput("segmented_matvec (19 segs × 64 out)", "outputs", || {
        black_box(big.segmented_matvec(&seg_codes, 64, 0.01, false));
        64
    });

    // ADC conversion alone.
    let adc = Adc::new(5, 16.0);
    let analogs: Vec<i64> = (0..4096).map(|_| rng.gen_range(2000) as i64 - 1000).collect();
    r.bench_throughput("adc convert", "conversions", || {
        let mut acc = 0i64;
        for &a in &analogs {
            acc += adc.convert(a) as i64;
        }
        black_box(acc);
        analogs.len() as u64
    });

    // Weight load.
    r.bench("load_columns (256 cols × 252 rows)", || {
        mac.load_columns(0, &cols);
    });
    r.finish();
}
