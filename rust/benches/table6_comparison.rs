//! Bench: regenerate Table VI (comparison with E-UPQ and XPert) and the
//! wordline-parallelism speedup computation behind the headline claims.

use cim_adapt::baselines::{eupq::eupq_latency_multiplier, xpert::xpert_latency_multiplier};
use cim_adapt::report::table6;
use cim_adapt::util::bench::{black_box, Runner};

fn main() {
    let mut r = Runner::new("table6_comparison");
    let t = table6(std::path::Path::new("artifacts"));
    r.table(&format!("{}", t.rendered));

    // The conversion-work multipliers behind the "64× / 16×" claims.
    r.table(&format!(
        "full 252-row segment: E-UPQ needs ×{} passes, XPert ×{} (ours: 1)",
        eupq_latency_multiplier(252, 4),
        xpert_latency_multiplier(252)
    ));
    r.bench("table6 end-to-end (3 morph flows)", || {
        black_box(table6(std::path::Path::new("artifacts")));
    });
    r.finish();
}
