//! Microbench: coordinator overhead — queue + batcher + metrics without
//! model execution cost (Sim backend), then the PJRT path when artifacts
//! exist. L3 must not be the bottleneck (DESIGN.md §7).

use std::path::Path;

use cim_adapt::arch::vgg9;
use cim_adapt::config::{MacroSpec, ServeConfig};
use cim_adapt::coordinator::server::{Backend, EdgeServer};
use cim_adapt::data::SynthCifar;
use cim_adapt::report::write_bench_summary;
use cim_adapt::util::bench::{black_box, Runner};
use cim_adapt::util::json::Json;

fn main() {
    let mut r = Runner::new("micro_serving");
    let arch = vgg9().scaled(0.125);
    let spec = MacroSpec::default();

    // Coordinator-only round trip (Sim backend, no XLA).
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        batch_timeout_us: 200,
        queue_depth: 4096,
        ..ServeConfig::default()
    };
    let h = EdgeServer::start(&cfg, Backend::Sim { num_classes: 10 }, &arch, &spec);
    let img = SynthCifar::sample(0, 0);
    r.bench("submit+wait roundtrip (Sim backend)", || {
        let t = h.submit(img.data.clone()).unwrap();
        black_box(t.wait().unwrap());
    });
    r.bench_throughput("pipelined 64-deep (Sim backend)", "req", || {
        let tickets: Vec<_> = (0..64)
            .map(|_| h.submit(img.data.clone()).unwrap())
            .collect();
        for t in tickets {
            black_box(t.wait().unwrap());
        }
        64
    });
    let sim_snap = h.shutdown();

    // Metrics collector at the latency-sample cap: on_complete must stay
    // O(1) once the 100k-sample ring is full (it was an O(n) Vec shift —
    // this arm regresses visibly if that ever comes back).
    {
        let m = cim_adapt::coordinator::metrics::Metrics::new();
        for i in 0..150_000u64 {
            m.on_complete(i);
        }
        r.bench("metrics on_complete at 100k-sample cap", || {
            m.on_complete(black_box(42));
        });
    }

    // PJRT path (skipped when artifacts are absent).
    let artifacts = Path::new("artifacts");
    if artifacts.join("vgg9_edge_meta.json").exists() {
        let probe = cim_adapt::runtime::ModelRuntime::load(artifacts, "vgg9_edge").unwrap();
        let served_arch = probe.meta.arch.clone();

        // Raw runtime latency (no coordinator).
        r.bench("PJRT infer b1 (raw runtime)", || {
            black_box(probe.infer("b1", &img.data).unwrap());
        });
        let mut batch8 = Vec::new();
        for _ in 0..8 {
            batch8.extend_from_slice(&img.data);
        }
        if probe.variants().contains(&"b8") {
            r.bench_throughput("PJRT infer b8 (raw runtime)", "img", || {
                black_box(probe.infer("b8", &batch8).unwrap());
                8
            });
        }
        drop(probe);

        let h = EdgeServer::start(
            &ServeConfig {
                workers: 1,
                max_batch: 8,
                batch_timeout_us: 500,
                queue_depth: 4096,
                ..ServeConfig::default()
            },
            Backend::Pjrt {
                artifact_dir: artifacts.to_path_buf(),
                model: "vgg9_edge".into(),
            },
            &served_arch,
            &spec,
        );
        r.bench_throughput("pipelined 32-deep (PJRT backend)", "req", || {
            let tickets: Vec<_> = (0..32)
                .map(|_| h.submit(img.data.clone()).unwrap())
                .collect();
            for t in tickets {
                black_box(t.wait().unwrap());
            }
            32
        });
        let snap = h.shutdown();
        r.table(&format!(
            "coordinator stats: mean batch {:.2}, p95 {} µs",
            snap.mean_batch, snap.latency.p95_us
        ));
    } else {
        r.table("(PJRT section skipped: run `make artifacts` first)");
    }

    // Machine-readable summary for cross-PR perf tracking.
    let summary = Json::obj()
        .with("bench", "micro_serving")
        .with("timings", r.results_json())
        .with("sim_serving", sim_snap.to_json());
    match write_bench_summary("serving", &summary) {
        Ok(path) => r.table(&format!("(wrote {})", path.display())),
        Err(e) => r.table(&format!("(BENCH_serving.json not written: {e})")),
    }
    r.finish();
}
