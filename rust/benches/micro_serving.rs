//! Microbench: coordinator overhead — queue + batcher + metrics without
//! model execution cost (Sim backend), then the PJRT path when artifacts
//! exist. L3 must not be the bottleneck (DESIGN.md §7).

use std::path::Path;

use cim_adapt::arch::vgg9;
use cim_adapt::config::{ExecutionMode, FleetConfig, MacroSpec, ServeConfig};
use cim_adapt::coordinator::server::{Backend, EdgeServer};
use cim_adapt::data::SynthCifar;
use cim_adapt::fleet::{QosFleet, QosSpec};
use cim_adapt::obs::FleetTrace;
use cim_adapt::report::write_bench_summary;
use cim_adapt::runtime::{ConcurrentFleet, Executor, ResponseView, StreamCodec};
use cim_adapt::util::bench::{black_box, Runner};
use cim_adapt::util::json::{nodes_allocated, Json};
use cim_adapt::util::threadpool::ThreadPool;

fn main() {
    let mut r = Runner::new("micro_serving");
    let arch = vgg9().scaled(0.125);
    let spec = MacroSpec::default();

    // Coordinator-only round trip (Sim backend, no XLA).
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        batch_timeout_us: 200,
        queue_depth: 4096,
        ..ServeConfig::default()
    };
    let h = EdgeServer::start(&cfg, Backend::Sim { num_classes: 10 }, &arch, &spec);
    let img = SynthCifar::sample(0, 0);
    r.bench("submit+wait roundtrip (Sim backend)", || {
        let t = h.submit(img.data.clone()).unwrap();
        black_box(t.wait().unwrap());
    });
    r.bench_throughput("pipelined 64-deep (Sim backend)", "req", || {
        let tickets: Vec<_> = (0..64)
            .map(|_| h.submit(img.data.clone()).unwrap())
            .collect();
        for t in tickets {
            black_box(t.wait().unwrap());
        }
        64
    });
    let sim_snap = h.shutdown();

    // Metrics collector at the latency-sample cap: on_complete must stay
    // O(1) once the 100k-sample ring is full (it was an O(n) Vec shift —
    // this arm regresses visibly if that ever comes back).
    {
        let m = cim_adapt::coordinator::metrics::Metrics::new();
        for i in 0..150_000u64 {
            m.on_complete(i);
        }
        r.bench("metrics on_complete at 100k-sample cap", || {
            m.on_complete(black_box(42));
        });
    }

    // Legacy pool vs work-stealing executor on the same job shape: 64
    // small tasks all submitted to one entry point, so the executor arm
    // exercises stealing. Steal/pop splits are timing-dependent —
    // reported for information, never compared as exact counters.
    {
        let pool = ThreadPool::new(4);
        r.bench_throughput("64 jobs: legacy ThreadPool::run_all", "job", || {
            let jobs: Vec<_> = (0..64u64).map(|i| move || black_box(i * i)).collect();
            black_box(pool.run_all(jobs));
            64
        });
        let exec = Executor::new(4);
        r.bench_throughput("64 jobs: work-stealing executor", "job", || {
            let (tx, rx) = std::sync::mpsc::channel::<u64>();
            for i in 0..64u64 {
                let tx = tx.clone();
                // Pile every task onto worker 0's deque: throughput then
                // depends on the other workers stealing the excess.
                exec.spawn_at(0, move || {
                    let _ = tx.send(black_box(i * i));
                });
            }
            drop(tx);
            let mut done = 0u64;
            while rx.recv().is_ok() {
                done += 1;
            }
            assert_eq!(done, 64);
            64
        });
        let es = exec.stats();
        r.table(&format!(
            "executor counters: spawned {}, popped {}, stolen {}, executed {} \
             (steal split is timing-dependent — informational only)",
            es.spawned, es.popped, es.stolen, es.executed
        ));
    }

    // Tree vs streaming JSON on the wire path, plus the deterministic
    // node-allocation ledger (exact counters: the streaming codec must
    // allocate ZERO Json nodes, and its encoding must be byte-identical
    // to the tree writer's).
    let json_summary = {
        let mut wire = Vec::from(&br#"{"model":"edge","image":["#[..]);
        for i in 0..3072usize {
            if i > 0 {
                wire.push(b',');
            }
            wire.extend_from_slice(format!("{}", (i % 256) as f64 / 256.0).as_bytes());
        }
        wire.extend_from_slice(b"]}");
        let text = String::from_utf8(wire.clone()).unwrap();
        r.bench("parse 3072-pixel request: tree parser", || {
            black_box(Json::parse(&text).unwrap());
        });
        let mut codec = StreamCodec::new();
        r.bench("parse 3072-pixel request: streaming codec", || {
            black_box(codec.decode_request(&wire).unwrap().image().len());
        });

        let before = nodes_allocated();
        let tree = Json::parse(&text).unwrap();
        let tree_nodes = nodes_allocated() - before;
        black_box(&tree);
        let logits = [0.5f32, 2.0, -1.25, 0.0];
        let view = ResponseView {
            id: 7,
            class: 1,
            logits: &logits,
            latency_us: 42,
            device_cycles: 1000,
            batch_size: 8,
        };
        let before = nodes_allocated();
        codec.decode_request(&wire).unwrap();
        let streamed = codec.encode_response(view).to_vec();
        let stream_nodes = nodes_allocated() - before;
        assert_eq!(stream_nodes, 0, "wire path must allocate no Json nodes");
        let tree_resp = Json::obj()
            .with("id", 7u64)
            .with("class", 1usize)
            .with("logits", vec![0.5, 2.0, -1.25, 0.0])
            .with("latency_us", 42u64)
            .with("device_cycles", 1000u64)
            .with("batch_size", 8usize);
        assert_eq!(
            streamed,
            tree_resp.dump().into_bytes(),
            "streaming encode must match the tree writer byte-for-byte"
        );
        r.table(&format!(
            "json ledger: tree parse allocates {tree_nodes} nodes/request, streaming 0"
        ));
        Json::obj()
            .with("tree_nodes", tree_nodes)
            .with("stream_nodes", stream_nodes)
            .with("bytes_identical", 1u64)
    };

    // Deterministic serving scenario: the work-stealing runtime vs the
    // sequential virtual-clock twin on a fixed op script. Every counter
    // below is decision-level (virtual clock, not wall clock), so it is
    // bit-stable across machines and thread interleavings — the bench
    // aborts before writing the summary if equivalence ever breaks.
    let scenario = {
        let mut cfg = FleetConfig {
            num_macros: 2,
            coresident: true,
            execution: ExecutionMode::Twin,
            ..FleetConfig::default()
        };
        cfg.qos.insert(
            "m1".into(),
            QosSpec {
                burst: 2,
                ..QosSpec::default()
            },
        );
        let mut seq = QosFleet::new(&cfg, &spec);
        let seq_trace = FleetTrace::new(1 << 12);
        seq.fleet_mut().set_trace(Some(seq_trace.sink()));
        let mut con = ConcurrentFleet::new(&cfg, &spec, 3);
        let con_trace = FleetTrace::new(1 << 12);
        con.set_trace(Some(con_trace.sink()));
        for (i, s) in [0.04, 0.03, 0.05].iter().enumerate() {
            seq.register(&format!("m{i}"), vgg9().scaled(*s), false).unwrap();
            con.register(&format!("m{i}"), vgg9().scaled(*s), false).unwrap();
        }
        let img = vec![0.5f32; 64];
        // Fixed script: submits (0..2 = tenant), dispatches (3), compact (4).
        let script = [0usize, 1, 2, 3, 1, 1, 3, 0, 2, 4, 3, 0, 1, 1, 2, 3, 3, 4, 0, 3];
        let (mut admitted, mut rejected) = (0u64, 0u64);
        for &op in &script {
            if op < 3 {
                let a = seq.submit(&format!("m{op}"), vec![img.clone()]).unwrap();
                let b = con.submit(&format!("m{op}"), vec![img.clone()]).unwrap();
                assert_eq!(a, b, "admission decisions diverged");
                if a.is_admitted() {
                    admitted += 1;
                } else {
                    rejected += 1;
                }
            } else if op == 3 {
                let _ = seq.dispatch_next().unwrap();
                let _ = con.dispatch_next().unwrap();
            } else {
                let _ = seq.fleet_mut().compact().unwrap();
                let _ = con.compact().unwrap();
            }
        }
        let seq_out = seq.drain().unwrap();
        let con_out = con.drain().unwrap();
        assert_eq!(seq_out.len(), con_out.len(), "batch counts diverged");
        for (a, b) in seq_out.iter().zip(&con_out) {
            assert_eq!(a.model, b.model);
            assert_eq!(a.classes, b.classes);
            assert_eq!(a.logits, b.logits);
            assert_eq!(a.device_cycles, b.device_cycles);
            assert_eq!(a.reload_cycles, b.reload_cycles);
            assert_eq!(a.evicted, b.evicted);
        }
        let ss = seq.snapshot();
        let cs = con.snapshot();
        assert_eq!(ss.reload_cycles, cs.reload_cycles);
        assert_eq!(ss.aggregate(), cs.aggregate());
        assert_eq!(ss.tenant_aggregate(), cs.tenant_aggregate());
        assert_eq!(ss.qos_totals(), cs.qos_totals());
        let seq_events: Vec<_> = seq_trace.log.lock().unwrap().events().cloned().collect();
        let con_events: Vec<_> = con_trace.log.lock().unwrap().events().cloned().collect();
        assert_eq!(seq_events, con_events, "trace streams diverged");
        let audit = con_trace.audit.lock().unwrap().verify(&cs);
        assert!(audit.pass, "audit failed: {:?}", audit.first_divergence);
        let es = con.executor_stats();
        r.table(&format!(
            "serving scenario: {} batches, {admitted} admitted, {rejected} rejected, \
             {} twin events — concurrent ≡ sequential (audit pass)",
            con_out.len(),
            con_events.len()
        ));
        Json::obj()
            .with("admitted", admitted)
            .with("rejected", rejected)
            .with("batches", con_out.len())
            .with(
                "device_cycles",
                con_out.iter().map(|o| o.device_cycles).sum::<u64>(),
            )
            .with("reload_cycles", cs.reload_cycles)
            .with("twin_load_cycles", cs.twin_load_cycles())
            .with("twin_compute_cycles", cs.aggregate().compute_cycles)
            .with("events_total", con_events.len())
            // 0/1 verdicts: the asserts above abort the bench before the
            // summary is written, so a healthy run always reads 1.
            .with("decisions_match", 1u64)
            .with("events_identical", 1u64)
            .with("audit_pass", 1u64)
            // Informational only (timing-dependent): NOT an exact counter.
            .with("steals", es.stolen)
    };

    // PJRT path (skipped when artifacts are absent).
    let artifacts = Path::new("artifacts");
    if artifacts.join("vgg9_edge_meta.json").exists() {
        let probe = cim_adapt::runtime::ModelRuntime::load(artifacts, "vgg9_edge").unwrap();
        let served_arch = probe.meta.arch.clone();

        // Raw runtime latency (no coordinator).
        r.bench("PJRT infer b1 (raw runtime)", || {
            black_box(probe.infer("b1", &img.data).unwrap());
        });
        let mut batch8 = Vec::new();
        for _ in 0..8 {
            batch8.extend_from_slice(&img.data);
        }
        if probe.variants().contains(&"b8") {
            r.bench_throughput("PJRT infer b8 (raw runtime)", "img", || {
                black_box(probe.infer("b8", &batch8).unwrap());
                8
            });
        }
        drop(probe);

        let h = EdgeServer::start(
            &ServeConfig {
                workers: 1,
                max_batch: 8,
                batch_timeout_us: 500,
                queue_depth: 4096,
                ..ServeConfig::default()
            },
            Backend::Pjrt {
                artifact_dir: artifacts.to_path_buf(),
                model: "vgg9_edge".into(),
            },
            &served_arch,
            &spec,
        );
        r.bench_throughput("pipelined 32-deep (PJRT backend)", "req", || {
            let tickets: Vec<_> = (0..32)
                .map(|_| h.submit(img.data.clone()).unwrap())
                .collect();
            for t in tickets {
                black_box(t.wait().unwrap());
            }
            32
        });
        let snap = h.shutdown();
        r.table(&format!(
            "coordinator stats: mean batch {:.2}, p95 {} µs",
            snap.mean_batch, snap.latency.p95_us
        ));
    } else {
        r.table("(PJRT section skipped: run `make artifacts` first)");
    }

    // Machine-readable summary for cross-PR perf tracking.
    let summary = Json::obj()
        .with("bench", "micro_serving")
        .with("timings", r.results_json())
        .with("sim_serving", sim_snap.to_json())
        .with("json", json_summary)
        .with("serving_scenario", scenario);
    match write_bench_summary("serving", &summary) {
        Ok(path) => r.table(&format!("(wrote {})", path.display())),
        Err(e) => r.table(&format!("(BENCH_serving.json not written: {e})")),
    }
    r.finish();
}
