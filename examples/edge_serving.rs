//! Edge serving: batched inference through the full L3 coordinator.
//!
//! Drives the adapted model with a Poisson open-loop workload, reporting
//! throughput, latency percentiles, batch formation and the CIM device
//! model (macro reloads + compute cycles) — the end-to-end deployment
//! story of the paper's system.
//!
//! ```bash
//! cargo run --release --example edge_serving -- --requests 512 --rate 800
//! ```
//!
//! `--runtime concurrent` switches to the work-stealing fleet runtime
//! instead: three twin-backed tenants share the macro pool, admission
//! and pricing stay sequential on this thread while forward passes
//! overlap on the executor's workers, and the run ends with the
//! four-ledger audit over the merged trace. Needs no PJRT artifacts:
//!
//! ```bash
//! cargo run --release --example edge_serving -- --runtime concurrent --requests 256
//! ```

use std::path::Path;
use std::time::{Duration, Instant};

use cim_adapt::arch::vgg9;
use cim_adapt::config::{ExecutionMode, FleetConfig, MacroSpec, ServeConfig};
use cim_adapt::coordinator::server::{Backend, EdgeServer};
use cim_adapt::data::{SynthCifar, NUM_CLASSES};
use cim_adapt::obs::FleetTrace;
use cim_adapt::runtime::{ConcurrentFleet, ModelRuntime};
use cim_adapt::util::cli::Args;
use cim_adapt::util::commas;
use cim_adapt::util::prng::Pcg;

/// Multi-tenant serving on the work-stealing runtime (digital twin
/// backend — runs anywhere, no artifacts). The sequential virtual-clock
/// driver would make the exact same decisions; `tests/proptests.rs`
/// proves that, and the trailing audit re-checks this very run.
fn run_concurrent(args: &Args) -> anyhow::Result<()> {
    let n = args.usize_or("requests", 256);
    let workers = args.usize_or("workers", 3);
    let cfg = FleetConfig {
        num_macros: args.usize_or("macros", 4),
        coresident: true,
        execution: ExecutionMode::Twin,
        ..FleetConfig::default()
    };
    let mut fleet = ConcurrentFleet::new(&cfg, &MacroSpec::default(), workers);
    let trace = FleetTrace::new(1 << 14);
    fleet.set_trace(Some(trace.sink()));
    let tenants = [("vision", 0.05), ("audio", 0.04), ("sensor", 0.03)];
    for (name, scale) in tenants {
        fleet.register(name, vgg9().scaled(scale), false)?;
    }
    println!(
        "serving {} twin tenants on {} macros, {} executor workers",
        tenants.len(),
        cfg.num_macros,
        workers
    );

    let t0 = Instant::now();
    let (mut admitted, mut rejected) = (0u64, 0u64);
    for k in 0..n {
        let (name, _) = tenants[k % tenants.len()];
        let img = SynthCifar::sample(k % NUM_CLASSES, 11_000 + k as u64);
        if fleet.submit(name, vec![img.data])?.is_admitted() {
            admitted += 1;
        } else {
            rejected += 1;
        }
        // Admission/compute overlap: dispatch as we go so forward passes
        // run on the workers while this thread admits the next requests.
        if k % 2 == 1 {
            fleet.dispatch_next()?;
        }
    }
    let outcomes = fleet.drain()?;
    let elapsed = t0.elapsed();
    let snap = fleet.snapshot();
    let es = fleet.executor_stats();
    let served: usize = outcomes.iter().map(|o| o.batch).sum();

    println!("\n── workload ──────────────────────────────");
    println!("requests          {n} ({admitted} admitted, {rejected} rejected)");
    println!("batches           {}", outcomes.len());
    println!(
        "throughput        {} images in {:.2}s ({:.0}/s)",
        served,
        elapsed.as_secs_f64(),
        served as f64 / elapsed.as_secs_f64().max(1e-9)
    );
    println!("\n── runtime ───────────────────────────────");
    println!(
        "executor          {} tasks: {} popped by owner, {} stolen",
        es.executed, es.popped, es.stolen
    );
    println!("\n── ledgers (device cycles) ───────────────");
    let agg = snap.aggregate();
    println!("compute           {}", commas(agg.compute_cycles));
    println!("reload            {}", commas(snap.reload_cycles));
    println!("migration         {}", commas(snap.migration_cycles));
    let audit = trace.audit.lock().unwrap().verify(&snap);
    let events = trace.log.lock().unwrap().events().count();
    println!(
        "audit             {} ({events} trace events, 4 ledgers re-derived)",
        if audit.pass { "PASS" } else { "FAIL" }
    );
    anyhow::ensure!(audit.pass, "ledger audit failed: {:?}", audit.first_divergence);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    cim_adapt::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    match args.str_or("runtime", "legacy") {
        "concurrent" => return run_concurrent(&args),
        "legacy" => {}
        other => anyhow::bail!("unknown --runtime '{other}' (legacy|concurrent)"),
    }
    let n = args.usize_or("requests", 512);
    let rate = args.f64_or("rate", 800.0); // requests/second offered
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("vgg9_edge_meta.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }

    let probe = ModelRuntime::load(&artifacts, "vgg9_edge")?;
    let arch = probe.meta.arch.clone();
    drop(probe);

    let cfg = ServeConfig {
        max_batch: args.usize_or("batch", 8),
        batch_timeout_us: args.u64_or("timeout-us", 2000),
        workers: args.usize_or("workers", 2),
        queue_depth: 512,
        num_macros: args.usize_or("macros", 4),
        clock_mhz: 200.0,
    };
    println!(
        "serving vgg9_edge: batch≤{}, {} workers, {} physical macros",
        cfg.max_batch, cfg.workers, cfg.num_macros
    );
    let handle = EdgeServer::start(
        &cfg,
        Backend::Pjrt {
            artifact_dir: artifacts.clone(),
            model: "vgg9_edge".into(),
        },
        &arch,
        &MacroSpec::default(),
    );
    println!(
        "plan: {} logical macros / {} physical → {} reloads per inference pass",
        handle.plan.logical_macros, handle.plan.physical_macros, handle.plan.reloads_per_inference
    );

    // Open-loop Poisson arrivals; a collector thread awaits responses.
    let mut rng = Pcg::new(42);
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    let t0 = Instant::now();
    let mut submitted = 0usize;
    let mut rejected = 0usize;
    std::thread::scope(|s| {
        for k in 0..n {
            let cls = k % NUM_CLASSES;
            let img = SynthCifar::sample(cls, 11_000 + k as u64);
            match handle.submit(img.data) {
                Ok(t) => {
                    submitted += 1;
                    let done_tx = done_tx.clone();
                    s.spawn(move || {
                        let r = t.wait();
                        let _ = done_tx.send(r.map(|resp| (cls, resp)));
                    });
                }
                Err(_) => rejected += 1,
            }
            let gap = rng.exponential(rate);
            std::thread::sleep(Duration::from_secs_f64(gap));
        }
        drop(done_tx);
        let mut correct = 0usize;
        let mut device_cycles_per_req = Vec::new();
        for msg in done_rx.iter() {
            if let Ok((cls, resp)) = msg {
                if resp.class == cls {
                    correct += 1;
                }
                device_cycles_per_req.push(resp.device_cycles);
            }
        }
        let elapsed = t0.elapsed();
        let m = handle.shutdown();
        println!("\n── workload ──────────────────────────────");
        println!("offered rate      {rate:.0} rps (Poisson)");
        println!("submitted         {submitted} ({rejected} rejected by backpressure)");
        println!("completed         {} in {:.2}s", m.completed, elapsed.as_secs_f64());
        println!("throughput        {:.0} rps", m.completed as f64 / elapsed.as_secs_f64());
        println!("accuracy          {:.1}%", correct as f64 / m.completed.max(1) as f64 * 100.0);
        println!("\n── serving ───────────────────────────────");
        println!("batches           {} (mean size {:.2})", m.batches, m.mean_batch);
        println!(
            "latency           p50 {}µs  p95 {}µs  p99 {}µs  max {}µs",
            m.latency.p50_us, m.latency.p95_us, m.latency.p99_us, m.latency.max_us
        );
        println!("\n── CIM device model (200 MHz) ─────────────");
        println!("compute+reload    {} cycles total", commas(m.device_cycles));
        println!("weight reloads    {}", m.weight_reloads);
        println!(
            "device time       {:.2} ms ({:.1} µs/request)",
            m.device_cycles as f64 / 200e6 * 1e3,
            m.device_cycles as f64 / 200.0 / m.completed.max(1) as f64
        );
        if let Some(&c) = device_cycles_per_req.first() {
            println!("cycles/request    {} (steady state)", commas(c));
        }
    });
    Ok(())
}
