//! Edge serving: batched inference through the full L3 coordinator.
//!
//! Drives the adapted model with a Poisson open-loop workload, reporting
//! throughput, latency percentiles, batch formation and the CIM device
//! model (macro reloads + compute cycles) — the end-to-end deployment
//! story of the paper's system.
//!
//! ```bash
//! cargo run --release --example edge_serving -- --requests 512 --rate 800
//! ```

use std::path::Path;
use std::time::{Duration, Instant};

use cim_adapt::config::{MacroSpec, ServeConfig};
use cim_adapt::coordinator::server::{Backend, EdgeServer};
use cim_adapt::data::{SynthCifar, NUM_CLASSES};
use cim_adapt::runtime::ModelRuntime;
use cim_adapt::util::cli::Args;
use cim_adapt::util::commas;
use cim_adapt::util::prng::Pcg;

fn main() -> anyhow::Result<()> {
    cim_adapt::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let n = args.usize_or("requests", 512);
    let rate = args.f64_or("rate", 800.0); // requests/second offered
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("vgg9_edge_meta.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }

    let probe = ModelRuntime::load(&artifacts, "vgg9_edge")?;
    let arch = probe.meta.arch.clone();
    drop(probe);

    let cfg = ServeConfig {
        max_batch: args.usize_or("batch", 8),
        batch_timeout_us: args.u64_or("timeout-us", 2000),
        workers: args.usize_or("workers", 2),
        queue_depth: 512,
        num_macros: args.usize_or("macros", 4),
        clock_mhz: 200.0,
    };
    println!(
        "serving vgg9_edge: batch≤{}, {} workers, {} physical macros",
        cfg.max_batch, cfg.workers, cfg.num_macros
    );
    let handle = EdgeServer::start(
        &cfg,
        Backend::Pjrt {
            artifact_dir: artifacts.clone(),
            model: "vgg9_edge".into(),
        },
        &arch,
        &MacroSpec::default(),
    );
    println!(
        "plan: {} logical macros / {} physical → {} reloads per inference pass",
        handle.plan.logical_macros, handle.plan.physical_macros, handle.plan.reloads_per_inference
    );

    // Open-loop Poisson arrivals; a collector thread awaits responses.
    let mut rng = Pcg::new(42);
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    let t0 = Instant::now();
    let mut submitted = 0usize;
    let mut rejected = 0usize;
    std::thread::scope(|s| {
        for k in 0..n {
            let cls = k % NUM_CLASSES;
            let img = SynthCifar::sample(cls, 11_000 + k as u64);
            match handle.submit(img.data) {
                Ok(t) => {
                    submitted += 1;
                    let done_tx = done_tx.clone();
                    s.spawn(move || {
                        let r = t.wait();
                        let _ = done_tx.send(r.map(|resp| (cls, resp)));
                    });
                }
                Err(_) => rejected += 1,
            }
            let gap = rng.exponential(rate);
            std::thread::sleep(Duration::from_secs_f64(gap));
        }
        drop(done_tx);
        let mut correct = 0usize;
        let mut device_cycles_per_req = Vec::new();
        for msg in done_rx.iter() {
            if let Ok((cls, resp)) = msg {
                if resp.class == cls {
                    correct += 1;
                }
                device_cycles_per_req.push(resp.device_cycles);
            }
        }
        let elapsed = t0.elapsed();
        let m = handle.shutdown();
        println!("\n── workload ──────────────────────────────");
        println!("offered rate      {rate:.0} rps (Poisson)");
        println!("submitted         {submitted} ({rejected} rejected by backpressure)");
        println!("completed         {} in {:.2}s", m.completed, elapsed.as_secs_f64());
        println!("throughput        {:.0} rps", m.completed as f64 / elapsed.as_secs_f64());
        println!("accuracy          {:.1}%", correct as f64 / m.completed.max(1) as f64 * 100.0);
        println!("\n── serving ───────────────────────────────");
        println!("batches           {} (mean size {:.2})", m.batches, m.mean_batch);
        println!(
            "latency           p50 {}µs  p95 {}µs  p99 {}µs  max {}µs",
            m.latency.p50_us, m.latency.p95_us, m.latency.p99_us, m.latency.max_us
        );
        println!("\n── CIM device model (200 MHz) ─────────────");
        println!("compute+reload    {} cycles total", commas(m.device_cycles));
        println!("weight reloads    {}", m.weight_reloads);
        println!(
            "device time       {:.2} ms ({:.1} µs/request)",
            m.device_cycles as f64 / 200e6 * 1e3,
            m.device_cycles as f64 / 200.0 / m.completed.max(1) as f64
        );
        if let Some(&c) = device_cycles_per_req.first() {
            println!("cycles/request    {} (steady state)", commas(c));
        }
    });
    Ok(())
}
