//! QoS-aware fleet dispatch: the overload story from `rust/README.md`'s
//! "QoS & admission control" section, on the deterministic core.
//!
//! Three tenants overload a 1-macro co-resident pool: a latency-critical
//! `hi` tenant interleaved behind two throughput tenants. The example
//! runs the same submit script through the FIFO baseline, the priority
//! dispatcher, and priority + admission control (budget + a hard rate
//! cap on the greediest tenant), printing the exact virtual-clock
//! counters — the same three arms `benches/micro_fleet.rs` gates in CI.
//!
//! ```bash
//! cargo run --release --example fleet_qos -- --rounds 8
//! ```

use std::collections::BTreeMap;

use cim_adapt::arch::by_name;
use cim_adapt::config::{ExecutionMode, FleetConfig, MacroSpec};
use cim_adapt::data::SynthCifar;
use cim_adapt::fleet::{QosClass, QosFleet, SchedMode};
use cim_adapt::latency::model_cost;
use cim_adapt::util::cli::Args;
use cim_adapt::util::commas;

struct ArmReport {
    name: &'static str,
    reload_cycles: u64,
    hi_load: u64,
    hi_delay: u64,
    admitted: u64,
    rejected: u64,
    deferred: u64,
}

/// One arm of the overload scenario. **Keep in sync with
/// `qos_overload_mix` in `rust/benches/micro_fleet.rs`** — the bench is
/// the CI-gated source of truth (exact counters in `BENCH_fleet.json`);
/// this example mirrors it so the printed numbers match the README.
fn run_arm(
    name: &'static str,
    sched: SchedMode,
    classes: bool,
    admission: bool,
    rounds: usize,
) -> ArmReport {
    let spec = MacroSpec::default();
    let scaled = |s: f64| by_name("vgg9").unwrap().scaled(s);
    let (hi, lo1, lo2) = (scaled(0.04), scaled(0.03), scaled(0.05));
    // Budget: resident passes fit, every hot-swap projects over.
    let pass2 = |a: &cim_adapt::arch::ModelArch| model_cost(a, &spec).pass_cycles(2);
    let budget = pass2(&hi).max(pass2(&lo1)).max(pass2(&lo2)) + 40;
    let mut cfg = FleetConfig {
        num_macros: 1,
        coresident: true,
        execution: ExecutionMode::Twin,
        sched,
        qos_aging_cycles: 1_000_000,
        admit_budget_cycles: if admission { budget } else { 0 },
        ..FleetConfig::default()
    };
    if classes {
        cfg.qos.entry("hi".into()).or_default().class = QosClass::Interactive;
        cfg.qos.entry("lo1".into()).or_default().class = QosClass::Batch;
        cfg.qos.entry("lo2".into()).or_default().class = QosClass::Batch;
    }
    if admission {
        // Hard cap: only lo2's first two batches are admitted.
        cfg.qos.entry("lo2".into()).or_default().burst = 4;
    }
    let mut fleet = QosFleet::new(&cfg, &spec);
    fleet.register("hi", hi, false).unwrap();
    fleet.register("lo1", lo1, false).unwrap();
    fleet.register("lo2", lo2, false).unwrap();
    let batch: Vec<Vec<f32>> = (0..2).map(|k| SynthCifar::sample(k, k as u64).data).collect();
    for _ in 0..rounds {
        for m in ["lo1", "lo2", "hi"] {
            let _ = fleet.submit(m, batch.clone()).unwrap();
        }
    }
    fleet.drain().unwrap();
    let snap = fleet.snapshot();
    assert_eq!(snap.reload_cycles, snap.macro_load_cycles());
    assert_eq!(snap.twin_load_cycles(), snap.reload_cycles);
    let tenants: BTreeMap<_, _> = snap.tenant_stats.iter().cloned().collect();
    let qos: BTreeMap<_, _> = snap.qos_stats.iter().cloned().collect();
    let totals = snap.qos_totals();
    ArmReport {
        name,
        reload_cycles: snap.reload_cycles,
        hi_load: tenants["hi"].load_cycles,
        hi_delay: qos["hi"].queue_delay_cycles,
        admitted: totals.admitted,
        rejected: totals.rejected,
        deferred: totals.deferred,
    }
}

fn main() -> anyhow::Result<()> {
    cim_adapt::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let rounds = args.usize_or("rounds", 8);

    println!(
        "overload: 3 tenants (108+82+139 BLs) on one 256-column macro, \
         {rounds} interleaved rounds of 2-image batches\n"
    );
    let arms = [
        run_arm("fifo", SchedMode::Fifo, false, false, rounds),
        run_arm("priority", SchedMode::Qos, true, false, rounds),
        run_arm("priority+admission", SchedMode::Qos, true, true, rounds),
    ];
    println!(
        "{:<20} {:>14} {:>12} {:>14} {:>9} {:>9} {:>9}",
        "arm", "reload cycles", "hi load", "hi delay", "admitted", "rejected", "deferred"
    );
    for a in &arms {
        println!(
            "{:<20} {:>14} {:>12} {:>14} {:>9} {:>9} {:>9}",
            a.name,
            commas(a.reload_cycles),
            commas(a.hi_load),
            commas(a.hi_delay),
            a.admitted,
            a.rejected,
            a.deferred
        );
    }
    let (ff, pr, ad) = (&arms[0], &arms[1], &arms[2]);
    println!(
        "\npriority cuts the hi tenant's reload thrash {}→{} cycles and its queue \
         delay {}→{}; admission also drops total reloads {}→{} by refusing {} \
         requests and deferring {} over-budget swaps.",
        commas(ff.hi_load),
        commas(pr.hi_load),
        commas(ff.hi_delay),
        commas(pr.hi_delay),
        commas(ff.reload_cycles),
        commas(ad.reload_cycles),
        ad.rejected,
        ad.deferred
    );
    Ok(())
}
