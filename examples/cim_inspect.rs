//! CIM macro microscope: run one convolution layer through the bit-exact
//! digital twin, comparing quantized vs ideal outputs and showing the
//! cycle accounting — a didactic tour of Figs. 1–3 and Eq. 7.
//!
//! ```bash
//! cargo run --release --example cim_inspect
//! cargo run --release --example cim_inspect -- --channels 56 --filters 8 --s-adc 8
//! ```

use cim_adapt::cim::{CimMacro, WeightCell};
use cim_adapt::config::MacroSpec;
use cim_adapt::quant::psum::segment_inputs;
use cim_adapt::util::cli::Args;
use cim_adapt::util::prng::Pcg;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let c_in = args.usize_or("channels", 56);
    let n_out = args.usize_or("filters", 6);
    let s_adc = args.f64_or("s-adc", 16.0) as f32;
    let spec = MacroSpec::default();
    let cpb = spec.channels_per_bl(3);
    let k2 = 9;

    println!("CIM macro: {}×{} cells, {}b weights, {}b DAC, {}b ADC ×{}",
        spec.wordlines, spec.bitlines, spec.weight_bits, spec.dac_bits,
        spec.adc_bits, spec.num_adcs);
    println!("layer: {c_in} input channels × 3×3 → {n_out} filters");

    // Segment the layer like Fig. 9.
    let segs = segment_inputs(c_in, 3, cpb);
    println!("wordline segments: {} ({} channels/bitline max)", segs.len(), cpb);
    for (i, (lo, hi)) in segs.iter().enumerate() {
        println!("  segment {i}: rows [{lo}, {hi}) = {} channels", (hi - lo) / k2);
    }

    // Random 4-bit weights + codes.
    let mut rng = Pcg::new(args.u64_or("seed", 1));
    let mut mac = CimMacro::new(spec, 1.0, s_adc);
    let total_rows = c_in * k2;
    let weights: Vec<Vec<i32>> = (0..n_out)
        .map(|_| (0..total_rows).map(|_| rng.gen_range(15) as i32 - 7).collect())
        .collect();
    for (si, (lo, hi)) in segs.iter().enumerate() {
        let cols: Vec<Vec<WeightCell>> = weights
            .iter()
            .map(|w| w[*lo..*hi].iter().map(|&v| WeightCell::saturating(v, 4)).collect())
            .collect();
        mac.load_columns(si * n_out, &cols);
    }
    println!("\nloaded {} bitline columns ({} cells occupied, {:.1}% of macro)",
        segs.len() * n_out,
        mac.array.occupied_cells(),
        mac.array.occupied_cells() as f64 / spec.cells() as f64 * 100.0);

    // One input patch.
    let codes: Vec<i32> = (0..total_rows).map(|_| rng.gen_range(16) as i32).collect();
    let seg_codes: Vec<Vec<i32>> = segs.iter().map(|(lo, hi)| codes[*lo..*hi].to_vec()).collect();

    let quantized = mac.segmented_matvec(&seg_codes, n_out, 1.0, false);
    let ideal = mac.ideal_matvec(&seg_codes, n_out, 1.0);
    println!("\n{:>8} {:>12} {:>12} {:>10}", "filter", "ideal", "quantized", "error");
    for f in 0..n_out {
        println!(
            "{f:>8} {:>12.1} {:>12.1} {:>9.1}%",
            ideal[f],
            quantized[f],
            if ideal[f].abs() > 1e-9 {
                (quantized[f] - ideal[f]).abs() / ideal[f].abs() * 100.0
            } else {
                0.0
            }
        );
    }

    let st = mac.stats;
    println!("\nhardware counters:");
    println!("  weight loads      {} ({} cycles)", st.reloads, st.load_cycles);
    println!("  compute cycles    {}", st.compute_cycles);
    println!("  ADC conversions   {}", st.conversions);
    println!(
        "  per output: {} segments × (1 evaluate + {} ADC rounds)",
        segs.len(),
        n_out.div_ceil(spec.num_adcs)
    );
    println!("\npower-of-two scaling: S_W·S_ADC snapped to shift — rerun with pow2:");
    let q_pow2 = mac.segmented_matvec(&seg_codes, n_out, 0.013, true);
    let q_exact = mac.segmented_matvec(&seg_codes, n_out, 0.013, false);
    for f in 0..n_out.min(3) {
        println!("  filter {f}: exact-scale {:.4} vs pow2-shift {:.4}", q_exact[f], q_pow2[f]);
    }
    Ok(())
}
