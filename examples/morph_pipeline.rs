//! Morphing pipeline walkthrough: Stage 1 on all three models across the
//! paper's four bitline budgets, printing the Tables III–V cost columns
//! and the macro-usage trajectory round by round.
//!
//! ```bash
//! cargo run --release --example morph_pipeline
//! cargo run --release --example morph_pipeline -- --model resnet18 --sparsity 0.5
//! ```

use cim_adapt::arch::by_name;
use cim_adapt::config::{MacroSpec, MorphConfig};
use cim_adapt::latency::model_cost;
use cim_adapt::morph::flow::morph_flow_synthetic;
use cim_adapt::util::cli::Args;
use cim_adapt::util::{commas, pct_delta};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let spec = MacroSpec::default();
    let sparsity = args.f64_or("sparsity", 0.4);
    let seed = args.u64_or("seed", 11);
    let models: Vec<&str> = match args.get("model") {
        Some(m) => vec![m],
        None => vec!["vgg9", "vgg16", "resnet18"],
    };

    for model in models {
        let arch = by_name(model)?;
        let base = model_cost(&arch, &spec);
        println!("\n================= {model} =================");
        println!(
            "baseline: {:.3}M params | {} BLs | load {} | compute {} cycles | psum {}",
            base.params as f64 / 1e6,
            commas(base.bls as u64),
            commas(base.load_weight_latency as u64),
            commas(base.computing_latency as u64),
            commas(base.psum_storage as u64),
        );
        for target in [8192usize, 4096, 1024, 512] {
            let cfg = MorphConfig {
                target_bl: target,
                ..MorphConfig::default()
            };
            let out = morph_flow_synthetic(&arch, &spec, &cfg, sparsity, seed);
            println!("\n-- budget {target} BLs --");
            for r in &out.rounds {
                println!(
                    "   round {}: prune → {:.3}M, expand ×{:.3} → {} BLs",
                    r.round + 1,
                    r.pruned_params as f64 / 1e6,
                    r.expansion_ratio,
                    commas(r.expanded_bls as u64)
                );
            }
            let c = &out.cost;
            println!(
                "   final: {:.3}M ({}) | BLs {} ({}) | MACs {} ({}) | usage {:.2}%",
                c.params as f64 / 1e6,
                pct_delta(c.params as f64, base.params as f64),
                commas(c.bls as u64),
                pct_delta(c.bls as f64, base.bls as f64),
                commas(c.macs as u64),
                pct_delta(c.macs as f64, base.macs as f64),
                out.macro_usage * 100.0
            );
            println!(
                "   latency: load {} ({}) | compute {} ({}) | psum {} ({})",
                commas(c.load_weight_latency as u64),
                pct_delta(c.load_weight_latency as f64, base.load_weight_latency as f64),
                commas(c.computing_latency as u64),
                pct_delta(c.computing_latency as f64, base.computing_latency as f64),
                commas(c.psum_storage as u64),
                pct_delta(c.psum_storage as f64, base.psum_storage as f64),
            );
        }
    }
    println!("\n(accuracy columns come from the reduced-scale QAT runs: `python -m compile.train --exp table3`)");
    Ok(())
}
