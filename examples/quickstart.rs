//! Quickstart: load the AOT-compiled adapted model and classify images.
//!
//! ```bash
//! make artifacts          # once: trains + exports the adapted model
//! cargo run --release --example quickstart
//! ```
//!
//! This is the minimal end-to-end path: python trained and adapted the
//! model offline (Stage 1 morphing + Stage 2 ADC-aware QAT), `aot.py`
//! lowered it to HLO text, and here Rust loads the artifact into a PJRT
//! CPU client and runs inference — no python at runtime.

use std::path::Path;

use cim_adapt::data::{SynthCifar, NUM_CLASSES};
use cim_adapt::runtime::ModelRuntime;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("vgg9_edge_meta.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }

    // 1. Load the artifact: parse HLO text, compile on the PJRT client.
    let rt = ModelRuntime::load(&artifacts, "vgg9_edge")?;
    println!("platform: {}", rt.platform());
    println!(
        "model: {} conv layers, {:.3}M params (morphed to {} bitlines)",
        rt.meta.arch.layers.len(),
        rt.meta.arch.params() as f64 / 1e6,
        cim_adapt::latency::model_cost(&rt.meta.arch, &cim_adapt::config::MacroSpec::default()).bls,
    );
    println!(
        "recorded accuracies: morphed {:.1}% → P1 {:.1}% → P2 {:.1}%",
        rt.meta.results.get("morphed_acc").as_f64().unwrap_or(0.0) * 100.0,
        rt.meta.results.get("p1_acc").as_f64().unwrap_or(0.0) * 100.0,
        rt.meta.results.get("p2_acc").as_f64().unwrap_or(0.0) * 100.0,
    );

    // 2. Classify a handful of fresh SynthCIFAR images.
    let mut correct = 0;
    let n = 30;
    for k in 0..n {
        let cls = k % NUM_CLASSES;
        let img = SynthCifar::sample(cls, 9000 + k as u64);
        let pred = rt.classify("b1", &img.data)?[0];
        if pred == cls {
            correct += 1;
        }
        if k < 10 {
            println!("  image class {cls} → predicted {pred} {}", if pred == cls { "✓" } else { "✗" });
        }
    }
    println!("accuracy on {n} fresh samples: {:.1}%", correct as f64 / n as f64 * 100.0);
    Ok(())
}
