//! Twin dataflow loop orderings: the buffer-traffic story from
//! `rust/README.md`'s "Twin dataflow & the buffer-traffic ledger"
//! section, on the deterministic core.
//!
//! One resident tenant is served under each loop ordering
//! (pixel-first / spatial-first / tap-reuse). All three execute
//! identical numerics — the example asserts bit-equal logits and equal
//! twin compute cycles, and that the executed compute equals the
//! analytic `computing_latency` by construction — and differ only in
//! the charged activation-buffer ledger, where tap-reuse strictly wins.
//! The same arms are the CI-gated `dataflow_scenario.*` counters in
//! `benches/micro_fleet.rs`.
//!
//! ```bash
//! cargo run --release --example fleet_dataflow -- --images 3
//! ```

use cim_adapt::arch::by_name;
use cim_adapt::config::{DataflowKind, ExecutionMode, FleetConfig, MacroSpec};
use cim_adapt::data::SynthCifar;
use cim_adapt::fleet::{scratch_allocs, Fleet};
use cim_adapt::latency::{model_cost, BufferTraffic};
use cim_adapt::util::cli::Args;
use cim_adapt::util::commas;

struct ArmReport {
    kind: DataflowKind,
    logits: Vec<Vec<f32>>,
    twin_compute: u64,
    buffer: BufferTraffic,
    steady_allocs: u64,
}

/// One loop-ordering arm. **Keep in sync with `dataflow_arm` in
/// `rust/benches/micro_fleet.rs`** — the bench is the CI-gated source
/// of truth (exact counters in `BENCH_fleet.json`); this example
/// mirrors it so the printed numbers match the README.
fn run_arm(kind: DataflowKind, images: usize) -> anyhow::Result<ArmReport> {
    let spec = MacroSpec::default();
    let cfg = FleetConfig {
        num_macros: 1,
        coresident: true,
        execution: ExecutionMode::Twin,
        dataflow: kind,
        ..FleetConfig::default()
    };
    let mut fleet = Fleet::new(&cfg, &spec);
    fleet.register("edge", by_name("vgg9").unwrap().scaled(0.04), false)?;
    let batch = vec![SynthCifar::sample(0, 0).data];
    // Warm-up pass grows the thread-local scratch to its high-water
    // mark (and pays the hot-swap); afterwards forwards are
    // allocation-free in steady state.
    let mut out = fleet.serve_batch("edge", &batch)?;
    let allocs_before = scratch_allocs();
    for _ in 1..images.max(1) {
        out = fleet.serve_batch("edge", &batch)?;
    }
    let steady_allocs = scratch_allocs() - allocs_before;
    let snap = fleet.snapshot();
    anyhow::ensure!(snap.buffer_twin == snap.buffer_fleet, "buffer ledger must be conserved");
    anyhow::ensure!(snap.tenant_buffer() == snap.buffer_fleet, "per-tenant view must agree");
    Ok(ArmReport {
        kind,
        logits: out.logits,
        twin_compute: snap.twin_stats.iter().map(|s| s.compute_cycles).sum(),
        buffer: snap.buffer_fleet,
        steady_allocs,
    })
}

fn main() -> anyhow::Result<()> {
    cim_adapt::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let images = args.usize_or("images", 3).max(1);

    println!(
        "dataflow: one 108-column vgg9 tenant on a 1-macro co-resident twin pool, \
         {images} identical 1-image serves under each loop ordering\n"
    );
    let arms = [
        run_arm(DataflowKind::PixelFirst, images)?,
        run_arm(DataflowKind::SpatialFirst, images)?,
        run_arm(DataflowKind::TapReuse, images)?,
    ];
    println!(
        "{:<15} {:>16} {:>16} {:>16} {:>14}",
        "ordering", "buffer reads", "buffer writes", "compute cycles", "steady allocs"
    );
    for a in &arms {
        println!(
            "{:<15} {:>16} {:>16} {:>16} {:>14}",
            a.kind.as_str(),
            commas(a.buffer.reads),
            commas(a.buffer.writes),
            commas(a.twin_compute),
            a.steady_allocs
        );
    }
    let (pf, sf, tr) = (&arms[0], &arms[1], &arms[2]);
    anyhow::ensure!(
        pf.logits == sf.logits && sf.logits == tr.logits,
        "loop order must not change the numerics"
    );
    anyhow::ensure!(pf.twin_compute == tr.twin_compute, "loop order must not change compute");
    anyhow::ensure!(
        tr.buffer.reads < sf.buffer.reads && sf.buffer.reads < pf.buffer.reads,
        "tap-reuse must strictly beat spatial-first and pixel-first on reads"
    );
    anyhow::ensure!(pf.buffer.writes == tr.buffer.writes, "writes are order-invariant");
    anyhow::ensure!(tr.steady_allocs == 0, "steady-state forwards must not allocate");
    let spec = MacroSpec::default();
    let arch = by_name("vgg9").unwrap().scaled(0.04);
    let per_image = model_cost(&arch, &spec).computing_latency as u64;
    let analytic = images as u64 * per_image;
    anyhow::ensure!(
        tr.twin_compute == analytic,
        "twin compute must equal the analytic latency ({} vs {})",
        tr.twin_compute,
        analytic
    );
    println!(
        "\nidentical logits and compute cycles in every arm; twin compute == analytic \
         computing_latency ({} = {images} x {}); tap-reuse cuts charged reads {} -> {} \
         ({:.1}x) with zero steady-state allocations.",
        commas(tr.twin_compute),
        commas(per_image),
        commas(pf.buffer.reads),
        commas(tr.buffer.reads),
        pf.buffer.reads as f64 / tr.buffer.reads as f64
    );
    Ok(())
}
