//! Content-addressed weight pools: the shared-backbone story from
//! `rust/README.md`'s "Content-addressed weight pools" section, on the
//! deterministic core.
//!
//! One 108-column base plus `--heads` fine-tuned heads (same backbone
//! cell-for-cell, divergent classifier) served round-robin on a 3-macro
//! pool, once with private copies and once with content-addressed
//! dedup. With private copies the family exceeds the pool and thrashes
//! evictions; with dedup each head borrows the backbone by reference,
//! pays only its delta, and the whole family stays resident. The same
//! arms are the CI-gated `dedup_scenario.*` counters in
//! `benches/micro_fleet.rs`.
//!
//! ```bash
//! cargo run --release --example fleet_dedup -- --heads 16 --rounds 16
//! ```

use cim_adapt::arch::by_name;
use cim_adapt::config::{FleetConfig, MacroSpec};
use cim_adapt::data::SynthCifar;
use cim_adapt::fleet::Fleet;
use cim_adapt::obs::FleetTrace;
use cim_adapt::util::cli::Args;
use cim_adapt::util::commas;

struct ArmReport {
    reload_cycles: u64,
    evictions: u64,
    logical_bls: usize,
    resident_bls: usize,
    shared_bls: usize,
    shared_cycles: u64,
    ratio: f64,
    audit_pass: bool,
}

/// One placement-mode arm. **Keep in sync with `dedup_backbone_mix` in
/// `rust/benches/micro_fleet.rs`** — the bench is the CI-gated source
/// of truth (exact counters in `BENCH_fleet.json`); this example
/// mirrors it so the printed numbers match the README.
fn run_arm(dedup: bool, heads: usize, rounds: usize) -> anyhow::Result<ArmReport> {
    let spec = MacroSpec::default();
    let cfg = FleetConfig {
        num_macros: 3,
        coresident: true,
        dedup,
        ..FleetConfig::default()
    };
    let trace = FleetTrace::default();
    let mut fleet = Fleet::new(&cfg, &spec);
    fleet.set_trace(Some(trace.sink()));
    fleet.register("base", by_name("vgg9").unwrap().scaled(0.04), false)?;
    let names: Vec<String> = std::iter::once("base".to_string())
        .chain((0..heads).map(|i| format!("h{i:02}")))
        .collect();
    for n in &names[1..] {
        fleet.register_derived(n, "base", false)?;
    }
    let batch = vec![SynthCifar::sample(3, 17).data];
    for _ in 0..rounds {
        for n in &names {
            fleet.serve_batch(n, &batch)?;
        }
    }
    let snap = fleet.snapshot();
    anyhow::ensure!(snap.reload_cycles == snap.macro_load_cycles(), "per-macro view must agree");
    anyhow::ensure!(snap.reload_cycles == snap.tenant_load_cycles(), "per-tenant view must agree");
    let audit = trace.audit.lock().unwrap().verify(&snap);
    anyhow::ensure!(audit.pass, "audit: {:?}", audit.first_divergence);
    Ok(ArmReport {
        reload_cycles: snap.reload_cycles,
        evictions: snap.evictions,
        logical_bls: snap.dedup_logical_bls,
        resident_bls: snap.dedup_resident_bls(),
        shared_bls: snap.dedup_shared_bls,
        shared_cycles: snap.dedup_shared_cycles,
        ratio: snap.dedup_ratio(),
        audit_pass: audit.pass,
    })
}

fn main() -> anyhow::Result<()> {
    cim_adapt::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let heads = args.usize_or("heads", 16).max(1);
    let rounds = args.usize_or("rounds", 16).max(1);

    println!(
        "dedup: one 108-column base + {heads} derived heads round-robin on a 3-macro \
         (768-column) pool, {rounds} rounds under each placement mode\n"
    );
    let private = run_arm(false, heads, rounds)?;
    let shared = run_arm(true, heads, rounds)?;
    println!(
        "{:<22} {:>16} {:>12} {:>22}",
        "placement", "reload cycles", "evictions", "five-view audit"
    );
    for (label, a) in [("private copies", &private), ("content-addressed", &shared)] {
        println!(
            "{:<22} {:>16} {:>12} {:>22}",
            label,
            commas(a.reload_cycles),
            a.evictions,
            if a.audit_pass { "PASS" } else { "FAIL" }
        );
    }
    anyhow::ensure!(
        shared.reload_cycles < private.reload_cycles,
        "dedup must strictly beat private-copy placement on reload cycles"
    );
    anyhow::ensure!(shared.evictions == 0, "the deduped family must fit without evictions");
    anyhow::ensure!(shared.ratio > 1.0, "the shared backbone must multiply capacity");
    anyhow::ensure!(private.logical_bls == 0, "dedup stats must stay zero with dedup off");
    println!(
        "\ndedup keeps {} logical bitlines resident in {} physical ({:.2}x) — {} borrowed \
         by reference, {} reload cycles avoided — and cuts charged reloads {} -> {} \
         ({:.1}x fewer).",
        commas(shared.logical_bls as u64),
        commas(shared.resident_bls as u64),
        shared.ratio,
        commas(shared.shared_bls as u64),
        commas(shared.shared_cycles),
        commas(private.reload_cycles),
        commas(shared.reload_cycles),
        private.reload_cycles as f64 / shared.reload_cycles.max(1) as f64
    );
    Ok(())
}
