//! Deterministic fleet tracing: the worked example behind the READMEs'
//! "Observability & tracing" section.
//!
//! Runs the QoS overload scenario (three tenants thrashing a 1-macro
//! co-resident twin pool — the same mix as `examples/fleet_qos.rs`) with
//! a [`FleetTrace`] attached, then shows everything the event stream
//! buys: the online four-ledger audit, per-tenant cycle histograms, the
//! Prometheus text exposition, the Chrome-trace JSON round-trip, and the
//! ASCII per-macro timeline.
//!
//! ```bash
//! cargo run --release --example fleet_trace -- --rounds 8
//! # optionally persist the exports:
//! cargo run --release --example fleet_trace -- --trace-out trace.json --metrics-out metrics.prom
//! ```
//!
//! The binary exposes the same exporters on a full threaded fleet run:
//! `cim-adapt fleet --trace-out trace.json --metrics-out metrics.prom`,
//! then `cim-adapt inspect --timeline trace.json`.

use cim_adapt::arch::by_name;
use cim_adapt::config::{ExecutionMode, FleetConfig, MacroSpec};
use cim_adapt::data::SynthCifar;
use cim_adapt::fleet::{QosClass, QosFleet, SchedMode};
use cim_adapt::obs::{ascii_timeline, events_from_chrome, EventKind, FleetTrace};
use cim_adapt::util::cli::Args;
use cim_adapt::util::commas;
use cim_adapt::util::json::Json;

fn main() -> anyhow::Result<()> {
    cim_adapt::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let rounds = args.usize_or("rounds", 8);

    let spec = MacroSpec::default();
    let mut cfg = FleetConfig {
        num_macros: 1,
        coresident: true,
        execution: ExecutionMode::Twin,
        sched: SchedMode::Qos,
        qos_aging_cycles: 1_000_000,
        ..FleetConfig::default()
    };
    cfg.qos.entry("hi".into()).or_default().class = QosClass::Interactive;
    cfg.qos.entry("lo1".into()).or_default().class = QosClass::Batch;
    cfg.qos.entry("lo2".into()).or_default().class = QosClass::Batch;

    let mut fleet = QosFleet::new(&cfg, &spec);
    // Attach the trace bundle BEFORE the scenario: every admit, reload,
    // migration, twin pass and dispatch lands in the ring, the
    // histograms, and the online auditor, stamped with the virtual
    // device clock (so two identical runs trace byte-identically).
    let trace = FleetTrace::default();
    fleet.fleet_mut().set_trace(Some(trace.sink()));
    let scaled = |s: f64| by_name("vgg9").unwrap().scaled(s);
    for (name, s) in [("hi", 0.04), ("lo1", 0.03), ("lo2", 0.05)] {
        fleet.register(name, scaled(s), false)?;
    }
    let batch: Vec<Vec<f32>> = (0..2).map(|k| SynthCifar::sample(k, k as u64).data).collect();
    println!(
        "overload: 3 tenants on one 256-column macro, {rounds} interleaved rounds, tracing on\n"
    );
    for _ in 0..rounds {
        for m in ["lo1", "lo2", "hi"] {
            let _ = fleet.submit(m, batch.clone())?;
        }
    }
    fleet.drain()?;
    let snap = fleet.snapshot();

    // 1. Per-kind event counts (lifetime totals, eviction-proof).
    {
        let log = trace.log.lock().unwrap();
        println!("event counts ({} total, {} dropped by the ring):", log.total(), log.dropped());
        for k in EventKind::ALL {
            let n = log.count(k);
            if n > 0 {
                println!("  {:<15} {n}", k.as_str());
            }
        }
    }

    // 2. The online four-ledger audit: the auditor saw only the event
    // stream, yet must re-derive the fleet/per-macro/per-tenant/twin
    // ledgers bit-exactly.
    let report = trace.audit.lock().unwrap().verify(&snap);
    println!(
        "\nledger audit: {} ({} checks over {} events)",
        if report.pass { "PASS" } else { "FAIL" },
        report.checks,
        report.events
    );
    if let Some(div) = &report.first_divergence {
        println!("  first divergence: {div}");
    }
    anyhow::ensure!(report.pass, "the audit must pass on an untampered run");

    // 3. Per-tenant queue-delay histogram ceilings (p50/p95, log buckets).
    {
        let hist = trace.hist.lock().unwrap();
        println!("\nqueue delay by tenant (log-bucket ceilings):");
        for (tenant, lanes) in hist.tenants() {
            println!(
                "  {tenant:<5} p50 ≤ {} cycles, p95 ≤ {} cycles ({} dispatches)",
                commas(lanes.queue_delay.quantile_ceiling(0.50)),
                commas(lanes.queue_delay.quantile_ceiling(0.95)),
                lanes.queue_delay.count()
            );
        }
    }

    // 4. Exporters: Chrome trace JSON (round-trips through the parser)
    // and Prometheus text.
    let tenants: Vec<String> = ["hi", "lo1", "lo2"].iter().map(|s| s.to_string()).collect();
    let chrome = trace.chrome(1, &tenants);
    let events = events_from_chrome(&Json::parse(&chrome.dump()).unwrap())?;
    println!("\nChrome trace: {} events round-tripped through Json::parse", events.len());
    if let Some(path) = args.get("trace-out") {
        std::fs::write(path, chrome.pretty())?;
        println!("  wrote {path} (open in chrome://tracing or ui.perfetto.dev)");
    }
    let prom = trace.prometheus(Some(report.pass));
    println!("Prometheus text: {} lines", prom.lines().count());
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(path, &prom)?;
        println!("  wrote {path}");
    }

    // 5. The ASCII timeline the binary renders via
    // `cim-adapt inspect --timeline`.
    println!("\n{}", ascii_timeline(&events, args.usize_or("width", 72)));
    Ok(())
}
