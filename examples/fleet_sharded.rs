//! Fleet-of-fleets: the worked example behind the READMEs' "Sharded
//! serving" section.
//!
//! 64 tenants hash onto 8 pools of 3 macros through the consistent-hash
//! ring. FNV's arc skew piles most of them onto one pool — far past its
//! column capacity — so a pool stuck with its hash-dealt tenants
//! reloads every one of them on every round. The example runs the same
//! request mix twice:
//!
//! * **static shard** — shed policy off: the skewed homes are final and
//!   the hot pool thrashs reloads forever;
//! * **sharded + migration** — `shed_threshold` armed: the hot pool
//!   sheds its hottest tenants to the coldest pools, paying bounded
//!   one-time transfer charges on the fifth ledger, and steady state
//!   reloads nothing.
//!
//! Both runs end with the five-ledger conservation audit: every pool's
//! four ledgers re-derived from its own event stream, plus the shard's
//! transfer ledger re-derived from the `MigratePool` events alone.
//!
//! ```bash
//! cargo run --release --example fleet_sharded
//! cargo run --release --example fleet_sharded -- --pools 8 --tenants 64 --rounds 6
//! ```
//!
//! The binary runs the same topology end to end:
//! `cim-adapt fleet --pools 8 --tenants 64`.
//! `benches/micro_fleet.rs` is the CI-gated source of truth for this
//! scenario (`shard_scenario.*` exact counters) — keep the two in sync.

use cim_adapt::arch::by_name;
use cim_adapt::config::{FleetConfig, MacroSpec};
use cim_adapt::data::SynthCifar;
use cim_adapt::fleet::ShardedFleet;
use cim_adapt::obs::FleetTrace;
use cim_adapt::util::cli::Args;
use cim_adapt::util::commas;

/// One full run of the overload mix; returns the shard and its traces
/// so the caller can audit and diff the arms.
fn run(
    pools: usize,
    tenants: usize,
    rounds: usize,
    shed_threshold: f64,
) -> anyhow::Result<(ShardedFleet, Vec<FleetTrace>, FleetTrace)> {
    let spec = MacroSpec::default();
    let cfg = FleetConfig {
        pools,
        num_macros: 3,
        coresident: true,
        shed_threshold,
        ..FleetConfig::default()
    };
    let mut shard = ShardedFleet::new(&cfg, &spec);
    let pool_traces: Vec<FleetTrace> =
        (0..shard.num_pools()).map(|_| FleetTrace::default()).collect();
    for (p, t) in pool_traces.iter().enumerate() {
        shard.pool_mut(p).set_trace(Some(t.sink()));
    }
    let shard_trace = FleetTrace::default();
    shard.set_trace(Some(shard_trace.sink()));

    let arch = by_name("vgg9")?.scaled(0.03); // ~82 columns per tenant
    let names: Vec<String> = (0..tenants).map(|i| format!("t{i:02}")).collect();
    for n in &names {
        shard.register(n, arch.clone(), false)?;
    }
    let batch = vec![SynthCifar::sample(1, 7).data];
    for _ in 0..rounds {
        for n in &names {
            shard.serve_batch(n, &batch)?;
        }
    }
    Ok((shard, pool_traces, shard_trace))
}

fn main() -> anyhow::Result<()> {
    cim_adapt::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let pools = args.usize_or("pools", 8);
    let tenants = args.usize_or("tenants", 64);
    let rounds = args.usize_or("rounds", 6);
    let threshold = args.f64_or("shed-threshold", 0.9);

    println!(
        "sharded serving: {tenants} tenants (~82 columns each) hashed over {pools} pools \
         of 3 macros, {rounds} round-robin rounds\n"
    );

    // Arm 1: static shard — the ring's homes are final.
    let (static_shard, ..) = run(pools, tenants, rounds, 0.0)?;
    let static_snap = static_shard.snapshot();
    println!("static shard (no migration): hash-dealt homes and their pressure");
    for p in 0..static_shard.num_pools() {
        let homed = static_snap.tenant_homes.iter().filter(|&&(_, h)| h == p).count();
        println!(
            "  pool {p}: {homed:>2} tenants, pressure {:.2}, {} reload cycles",
            static_shard.pressure(p),
            commas(static_snap.pools[p].reload_cycles)
        );
    }

    // Arm 2: same mix, shed policy armed.
    let (shard, pool_traces, shard_trace) = run(pools, tenants, rounds, threshold)?;
    let snap = shard.snapshot();
    println!("\nsharded + migration (shed threshold {threshold}): rebalanced homes");
    for p in 0..shard.num_pools() {
        let homed = snap.tenant_homes.iter().filter(|&&(_, h)| h == p).count();
        println!(
            "  pool {p}: {homed:>2} tenants, pressure {:.2}, {} reload cycles, \
             {} transfer cycles in",
            shard.pressure(p),
            commas(snap.pools[p].reload_cycles),
            commas(snap.pool_transfer_cycles[p])
        );
    }
    println!(
        "\ntransfer ledger: {} charged transfers, {} cycles at link cost {} \
         (shard total = Σ per-pool = Σ per-tenant)",
        snap.transfers,
        commas(snap.transfer_cycles),
        snap.link_cost
    );

    // The five-ledger conservation audit: each pool's four ledgers from
    // its own event stream, the transfer ledger from MigratePool events.
    let mut pass = true;
    for (p, t) in pool_traces.iter().enumerate() {
        pass &= t.audit.lock().unwrap().verify(&snap.pools[p]).pass;
    }
    let transfer_report = shard_trace.audit.lock().unwrap().verify_transfers(&snap);
    pass &= transfer_report.pass;
    println!(
        "five-ledger audit: {} ({} pools x 4 ledgers + transfer ledger, {} checks)",
        if pass { "PASS" } else { "FAIL" },
        shard.num_pools(),
        transfer_report.checks
    );
    anyhow::ensure!(pass, "conservation audit must pass on an untampered run");

    // The headline: one-time transfers beat steady-state thrash.
    println!(
        "\ntotal movement cycles (reload + migration + transfer) over {rounds} rounds:\n\
           static shard       {}\n\
           sharded+migration  {}  ({:.1}x fewer)",
        commas(static_snap.total_movement_cycles()),
        commas(snap.total_movement_cycles()),
        static_snap.total_movement_cycles() as f64 / snap.total_movement_cycles().max(1) as f64
    );

    // Elasticity: growing the fleet moves only the new pool's arc.
    let mut shard = shard;
    let before = snap.tenant_homes.clone();
    let (id, moved) = shard.add_pool()?;
    let after = shard.snapshot().tenant_homes;
    let strays = before
        .iter()
        .zip(&after)
        .filter(|((_, old), (_, new))| new != old && *new != id)
        .count();
    println!(
        "\nadd_pool -> pool {id}: {moved} of {tenants} tenants re-homed, \
         {strays} moved anywhere else (consistent-hash guarantee)"
    );
    Ok(())
}
